//! Wire protocol: typed, length-prefixed, CRC-checked messages with the
//! paper's 512 kB chunked transfer.
//!
//! DEFER's sockets carry four kinds of traffic: the model architecture
//! (meta JSON + HLO text), the weights array, intermediate inference
//! results, and control messages (chain wiring, shutdown). One header
//! layout covers all of them:
//!
//! ```text
//! magic   u32le  0x44454652 ("DEFR")
//! type    u8     MessageType
//! batch   u24le  frames coalesced in this message, minus one (0 = single)
//! frame   u64le  frame id (inference cycle number; 0 for config traffic)
//! wire    u64le  payload length on the wire (post-compression)
//! serial  u64le  serialized length (pre-compression, for decompressor)
//! count   u64le  f32 element count (0 for non-tensor payloads)
//! crc     u32le  CRC-32 over header bytes [0..40) + the wire payload
//! ```
//!
//! The batch field lives in what used to be the header pad bytes and is
//! stored biased by one, so an unbatched message (`batch == 1`) writes
//! zeros there — byte-identical to the pre-batching wire format. A
//! batched `Data`/`ResultMsg` carries the stacked activations of frames
//! `frame .. frame + batch` in one payload (one header, one container),
//! which is what amortizes the per-frame fixed costs.
//!
//! The payload follows in chunks of at most [`CHUNK_SIZE`] bytes — the
//! paper's "chunked data transfer (with a default size of 512kB per chunk)".
//! Chunking is observable by the link model: every chunk passes through the
//! configured [`crate::netem::Link`] shaper and the per-socket byte
//! counters, which is exactly where `nload` measured the paper's payloads.

pub mod crc32;

use std::io::{IoSlice, Read, Write};
use std::sync::Arc;

use crate::error::{DeferError, Result};
use crate::metrics::{zerocopy, ByteCounter};
use crate::netem::Link;
use crate::util::bufpool::BufPool;

/// Paper's default chunk size: 512 kB.
pub const CHUNK_SIZE: usize = 512 * 1024;
pub const MAGIC: u32 = 0x4445_4652; // "DEFR"
/// Refuse absurd payloads (corrupt headers) before allocating.
pub const MAX_PAYLOAD: u64 = 8 * 1024 * 1024 * 1024;
/// Max frames one message may coalesce (the header stores `batch - 1`
/// in 3 bytes).
pub const MAX_BATCH: u32 = 1 << 24;

/// Message discriminants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MessageType {
    /// Model architecture: meta JSON + HLO text (configuration step).
    ModelConfig = 1,
    /// Weights array (configuration step).
    Weights = 2,
    /// Intermediate activation (distributed inference step).
    Data = 3,
    /// Final result returning to the dispatcher.
    ResultMsg = 4,
    /// Orderly shutdown of the chain.
    Shutdown = 5,
    /// Configuration acknowledged; node is ready.
    Ready = 6,
    /// Recovery control: "re-send chunk `i` of frame `f`" (CRC failed).
    /// Rides the control mesh only — never appears on a fault-free wire.
    ChunkNack = 7,
    /// Recovery control: the re-sent chunk bytes answering a NACK.
    ChunkRetry = 8,
}

impl MessageType {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => MessageType::ModelConfig,
            2 => MessageType::Weights,
            3 => MessageType::Data,
            4 => MessageType::ResultMsg,
            5 => MessageType::Shutdown,
            6 => MessageType::Ready,
            7 => MessageType::ChunkNack,
            8 => MessageType::ChunkRetry,
            other => return Err(DeferError::Wire(format!("bad message type {other}"))),
        })
    }
}

/// Build a chunk NACK: "frame `frame`, chunk `chunk` failed its CRC —
/// re-send it". The chunk index travels in the payload (4 bytes LE) so
/// the header keeps its standard layout. The payload rides inline in the
/// [`WireFrame`] — a NACK burst under corruption allocates nothing.
pub fn chunk_nack(frame: u64, chunk: u32) -> WireFrame {
    WireFrame::new(
        MessageType::ChunkNack,
        frame,
        1,
        0,
        0,
        SharedPayload::inline(&chunk.to_le_bytes()),
    )
    .expect("batch 1 is always valid")
}

/// Build the reply to a NACK: the retained wire bytes of exactly that
/// chunk (per-chunk header + body, as cut by
/// [`crate::serial::chunked::chunk_payload_span`]).
pub fn chunk_retry(frame: u64, chunk: u32, bytes: &[u8]) -> Message {
    let mut payload = Vec::with_capacity(4 + bytes.len());
    payload.extend_from_slice(&chunk.to_le_bytes());
    payload.extend_from_slice(bytes);
    Message {
        msg_type: MessageType::ChunkRetry,
        frame,
        serialized_len: bytes.len() as u64,
        count: 0,
        batch: 1,
        payload,
    }
}

/// Parse a `ChunkNack`/`ChunkRetry` payload into (chunk index, trailing
/// bytes). For a NACK the trailing slice is empty; for a retry it is the
/// re-sent chunk span. Anything else is a protocol violation.
pub fn parse_chunk_control(msg: &Message) -> Result<(u32, &[u8])> {
    if !matches!(
        msg.msg_type,
        MessageType::ChunkNack | MessageType::ChunkRetry
    ) {
        return Err(DeferError::Wire(format!(
            "expected chunk control frame, got {:?}",
            msg.msg_type
        )));
    }
    if msg.payload.len() < 4 {
        return Err(DeferError::Wire(format!(
            "chunk control payload too short: {} bytes",
            msg.payload.len()
        )));
    }
    let chunk = u32::from_le_bytes(msg.payload[0..4].try_into().unwrap());
    Ok((chunk, &msg.payload[4..]))
}

/// A framed message (header + owned payload).
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub msg_type: MessageType,
    /// First member frame id; a batched message carries frames
    /// `frame .. frame + batch`.
    pub frame: u64,
    /// Pre-compression serialized size (decompressor input).
    pub serialized_len: u64,
    /// f32 element count for tensor payloads (total across the batch).
    pub count: u64,
    /// Logical frames coalesced in the payload (>= 1; 1 = unbatched).
    pub batch: u32,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn control(msg_type: MessageType) -> Self {
        Message {
            msg_type,
            frame: 0,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: Vec::new(),
        }
    }

    /// Header + payload size on the wire (what nload would count).
    pub fn wire_size(&self) -> u64 {
        HEADER_SIZE as u64 + self.payload.len() as u64
    }
}

pub const HEADER_SIZE: usize = 4 + 1 + 3 + 8 + 8 + 8 + 8 + 4;

#[allow(clippy::too_many_arguments)]
fn encode_header_parts(
    msg_type: MessageType,
    frame: u64,
    batch: u32,
    serialized_len: u64,
    count: u64,
    payload: &[u8],
) -> [u8; HEADER_SIZE] {
    let mut h = [0u8; HEADER_SIZE];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4] = msg_type as u8;
    // Batch count, biased by one, in the former pad bytes: an unbatched
    // message writes zeros, keeping the legacy wire bytes exactly.
    h[5..8].copy_from_slice(&(batch - 1).to_le_bytes()[..3]);
    h[8..16].copy_from_slice(&frame.to_le_bytes());
    h[16..24].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    h[24..32].copy_from_slice(&serialized_len.to_le_bytes());
    h[32..40].copy_from_slice(&count.to_le_bytes());
    // CRC covers the header fields too — a flipped frame id or length must
    // not pass silently (frame ids order the FIFO results). Streamed, so
    // header + payload are never concatenated (§Perf).
    let crc = crc32::finish(crc32::update(
        crc32::update(crc32::init(), &h[0..40]),
        payload,
    ));
    h[40..44].copy_from_slice(&crc.to_le_bytes());
    h
}

fn encode_header(msg: &Message) -> [u8; HEADER_SIZE] {
    encode_header_parts(
        msg.msg_type,
        msg.frame,
        msg.batch,
        msg.serialized_len,
        msg.count,
        &msg.payload,
    )
}

/// Charge the link shaper and byte counter for one message's wire bytes:
/// the header, then the payload in <=512 kB chunk steps — the *same*
/// sequence the pre-vectored writer charged, so shaped timing and
/// `RunReport` byte totals are independent of how many syscalls the
/// bytes actually leave in.
fn charge_wire(link: &Link, counter: &ByteCounter, payload: &[u8]) {
    link.shape(HEADER_SIZE);
    counter.add(HEADER_SIZE as u64);
    for chunk in payload.chunks(CHUNK_SIZE.max(1)) {
        link.shape(chunk.len());
        counter.add(chunk.len() as u64);
    }
}

/// `write_all` for the logical buffer `head || body` without ever
/// materializing the concatenation: vectored writes while the header has
/// unwritten bytes, plain writes for the payload tail. Resumes correctly
/// from a short write at any offset — mid-header, mid-payload, or exactly
/// at the iovec boundary.
pub fn write_all_vectored(
    w: &mut impl Write,
    head: &[u8],
    body: &[u8],
) -> std::io::Result<()> {
    let total = head.len() + body.len();
    let mut written = 0usize;
    while written < total {
        let res = if written < head.len() {
            let bufs = [IoSlice::new(&head[written..]), IoSlice::new(body)];
            w.write_vectored(&bufs)
        } else {
            w.write(&body[written - head.len()..])
        };
        match res {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Write one message: header, then the payload, leaving the process in as
/// few writes as the sink allows ([`Write::write_vectored`] — one syscall
/// for an unbuffered socket). The link shaper and byte counters observe
/// the header + <=512 kB chunk sequence exactly as before (§Perf:
/// vectored egress changed syscall count, not accounting).
pub fn write_message(
    w: &mut impl Write,
    msg: &Message,
    link: &Link,
    counter: &ByteCounter,
) -> Result<()> {
    if msg.batch == 0 || msg.batch > MAX_BATCH {
        return Err(DeferError::Wire(format!(
            "batch {} out of range 1..={MAX_BATCH}",
            msg.batch
        )));
    }
    let header = encode_header(msg);
    charge_wire(link, counter, &msg.payload);
    write_all_vectored(w, &header, &msg.payload)?;
    w.flush()?;
    Ok(())
}

/// Payload bytes of a [`WireFrame`]: either a few inline control bytes
/// (chunk NACKs, empty control frames — no heap traffic at all) or an
/// `Arc`-shared pooled buffer. Cloning is O(1); the buffer returns to its
/// [`BufPool`] when the last reference drops.
#[derive(Clone, Debug)]
pub enum SharedPayload {
    /// Small control payloads stored in place (<= [`INLINE_PAYLOAD`]).
    Inline { len: u8, buf: [u8; INLINE_PAYLOAD] },
    /// Frame-scale payloads, shared by reference.
    Shared(Arc<PayloadCell>),
}

/// Max payload bytes stored inline in a [`SharedPayload`].
pub const INLINE_PAYLOAD: usize = 24;

/// An owned payload buffer plus the pool it returns to on drop. This is
/// the zero-copy contract: the encoder fills the buffer once, and egress
/// queues, the retention ring, failover reroute and re-dispatch all hold
/// `Arc`s to this cell — nobody memcpys the bytes again.
#[derive(Debug, Default)]
pub struct PayloadCell {
    buf: Vec<u8>,
    pool: Option<Arc<BufPool>>,
}

impl Drop for PayloadCell {
    fn drop(&mut self) {
        if let Some(pool) = &self.pool {
            pool.put(std::mem::take(&mut self.buf));
        }
    }
}

impl SharedPayload {
    /// Inline payload (<= [`INLINE_PAYLOAD`] bytes; panics beyond — the
    /// wire layer only inlines its own fixed-size control payloads).
    pub fn inline(bytes: &[u8]) -> SharedPayload {
        assert!(bytes.len() <= INLINE_PAYLOAD, "inline payload too large");
        let mut buf = [0u8; INLINE_PAYLOAD];
        buf[..bytes.len()].copy_from_slice(bytes);
        SharedPayload::Inline {
            len: bytes.len() as u8,
            buf,
        }
    }

    /// Wrap an owned buffer (typically fresh from the encoder). `pool`
    /// receives the buffer back when the last clone drops.
    pub fn from_vec(buf: Vec<u8>, pool: Option<Arc<BufPool>>) -> SharedPayload {
        SharedPayload::Shared(Arc::new(PayloadCell { buf, pool }))
    }

    pub fn as_slice(&self) -> &[u8] {
        match self {
            SharedPayload::Inline { len, buf } => &buf[..*len as usize],
            SharedPayload::Shared(cell) => &cell.buf,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The bytes as an owned `Vec`. Zero-copy when this is the last
    /// reference to a shared cell (the buffer migrates out, bypassing
    /// the cell's pool return); a counted copy when other holders (e.g.
    /// the retention ring) still share it.
    pub fn into_vec(self) -> Vec<u8> {
        match self {
            SharedPayload::Inline { len, buf } => buf[..len as usize].to_vec(),
            SharedPayload::Shared(cell) => match Arc::try_unwrap(cell) {
                Ok(mut cell) => std::mem::take(&mut cell.buf),
                Err(cell) => {
                    if !cell.buf.is_empty() {
                        zerocopy::count_payload_copy();
                    }
                    cell.buf.clone()
                }
            },
        }
    }
}

/// One encoded message in wire form: the fixed 44-byte header (CRC
/// already computed) plus a [`SharedPayload`]. Built **once** by the
/// encoder; every consumer — egress queue, deal fan-out and failover
/// reroute, recovery retention ring, NACK responder, re-dispatch —
/// clones the `WireFrame` (an `Arc` bump) instead of the bytes.
#[derive(Clone, Debug)]
pub struct WireFrame {
    header: [u8; HEADER_SIZE],
    payload: SharedPayload,
}

impl WireFrame {
    pub fn new(
        msg_type: MessageType,
        frame: u64,
        batch: u32,
        serialized_len: u64,
        count: u64,
        payload: SharedPayload,
    ) -> Result<WireFrame> {
        if batch == 0 || batch > MAX_BATCH {
            return Err(DeferError::Wire(format!(
                "batch {batch} out of range 1..={MAX_BATCH}"
            )));
        }
        let header = encode_header_parts(
            msg_type,
            frame,
            batch,
            serialized_len,
            count,
            payload.as_slice(),
        );
        Ok(WireFrame { header, payload })
    }

    /// Bridge from the legacy owned-payload [`Message`] (control and
    /// config traffic). Small payloads inline; larger ones pay one
    /// counted copy — the data path builds [`WireFrame`]s natively and
    /// never comes through here.
    pub fn from_message(msg: &Message) -> Result<WireFrame> {
        let payload = if msg.payload.len() <= INLINE_PAYLOAD {
            SharedPayload::inline(&msg.payload)
        } else {
            zerocopy::count_payload_copy();
            SharedPayload::from_vec(msg.payload.clone(), None)
        };
        WireFrame::new(
            msg.msg_type,
            msg.frame,
            msg.batch,
            msg.serialized_len,
            msg.count,
            payload,
        )
    }

    pub fn msg_type(&self) -> MessageType {
        MessageType::from_u8(self.header[4]).expect("validated at construction")
    }

    pub fn frame(&self) -> u64 {
        u64::from_le_bytes(self.header[8..16].try_into().unwrap())
    }

    pub fn batch(&self) -> u32 {
        1 + u32::from_le_bytes([self.header[5], self.header[6], self.header[7], 0])
    }

    pub fn serialized_len(&self) -> u64 {
        u64::from_le_bytes(self.header[24..32].try_into().unwrap())
    }

    pub fn count(&self) -> u64 {
        u64::from_le_bytes(self.header[32..40].try_into().unwrap())
    }

    pub fn header_bytes(&self) -> &[u8; HEADER_SIZE] {
        &self.header
    }

    pub fn payload_bytes(&self) -> &[u8] {
        self.payload.as_slice()
    }

    pub fn shared_payload(&self) -> &SharedPayload {
        &self.payload
    }

    /// Header + payload size on the wire (what nload would count).
    pub fn wire_size(&self) -> u64 {
        HEADER_SIZE as u64 + self.payload.len() as u64
    }

    /// Charge shaper + counter for this frame's bytes without writing —
    /// callers pair this with [`WireFrame::write_to`] (TCP) or an
    /// in-process handoff (local pipes). Sequence identical to
    /// [`write_message`]'s.
    pub fn charge(&self, link: &Link, counter: &ByteCounter) {
        charge_wire(link, counter, self.payload.as_slice());
    }

    /// Write header + payload via vectored I/O (no flush — the caller
    /// owns buffering policy).
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write_all_vectored(w, &self.header, self.payload.as_slice())
    }

    /// The full wire image as one owned buffer (fault injection, tests).
    pub fn to_wire_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size() as usize);
        out.extend_from_slice(&self.header);
        out.extend_from_slice(self.payload.as_slice());
        out
    }

    /// Materialize a legacy [`Message`] view (copies the payload; used
    /// only off the hot path, e.g. fault injection).
    pub fn to_message(&self) -> Message {
        if !self.payload.is_empty() {
            zerocopy::count_payload_copy();
        }
        Message {
            msg_type: self.msg_type(),
            frame: self.frame(),
            serialized_len: self.serialized_len(),
            count: self.count(),
            batch: self.batch(),
            payload: self.payload.as_slice().to_vec(),
        }
    }

    /// Consume the frame into a [`Message`] — zero-copy when the payload
    /// is uniquely held (the in-process delivery path). No CRC pass: the
    /// bytes never left memory and the header was built validated.
    pub fn into_message(self) -> Message {
        Message {
            msg_type: self.msg_type(),
            frame: self.frame(),
            serialized_len: self.serialized_len(),
            count: self.count(),
            batch: self.batch(),
            payload: self.payload.into_vec(),
        }
    }
}

/// What travels through egress queues and local pipes: a structured
/// frame (never flattened — the zero-copy path) or pre-serialized raw
/// bytes (legacy control traffic, truncation fault injection).
#[derive(Clone, Debug)]
pub enum WireBuf {
    Frame(WireFrame),
    Raw(Vec<u8>),
}

impl WireBuf {
    /// Total wire bytes this buffer represents.
    pub fn len(&self) -> usize {
        match self {
            WireBuf::Frame(f) => f.wire_size() as usize,
            WireBuf::Raw(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The leading [`HEADER_SIZE`] bytes when present (routing metadata:
    /// type, frame id, batch). Raw buffers shorter than a header — e.g.
    /// truncation faults — return `None`.
    pub fn wire_header(&self) -> Option<&[u8]> {
        match self {
            WireBuf::Frame(f) => Some(&f.header[..]),
            WireBuf::Raw(b) if b.len() >= HEADER_SIZE => Some(&b[..HEADER_SIZE]),
            WireBuf::Raw(_) => None,
        }
    }
}

impl From<WireFrame> for WireBuf {
    fn from(f: WireFrame) -> WireBuf {
        WireBuf::Frame(f)
    }
}

impl From<Vec<u8>> for WireBuf {
    fn from(b: Vec<u8>) -> WireBuf {
        WireBuf::Raw(b)
    }
}

/// A parsed-and-validated message header whose payload has not been
/// read yet. Magic, type and size-cap checks happen in [`Header::parse`]
/// (before any payload allocation); the CRC — which covers the payload —
/// is verified in [`Header::into_message`]. Both the blocking reader and
/// the reactor's [`FrameAssembler`] build messages through this type, so
/// the two planes validate identically by construction.
#[derive(Clone, Debug)]
pub struct Header {
    pub msg_type: MessageType,
    pub frame: u64,
    /// Payload length on the wire (post-compression).
    pub wire_len: u64,
    pub serialized_len: u64,
    pub count: u64,
    pub batch: u32,
    crc_expect: u32,
    /// The raw header bytes, kept because the CRC covers bytes [0..40).
    raw: [u8; HEADER_SIZE],
}

impl Header {
    /// Parse and validate the fixed-size header: magic, message type,
    /// and the payload-size cap (refused before anything allocates).
    pub fn parse(raw: &[u8; HEADER_SIZE]) -> Result<Header> {
        let magic = u32::from_le_bytes(raw[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(DeferError::Wire(format!("bad magic {magic:#x}")));
        }
        let msg_type = MessageType::from_u8(raw[4])?;
        let batch = 1 + u32::from_le_bytes([raw[5], raw[6], raw[7], 0]);
        let frame = u64::from_le_bytes(raw[8..16].try_into().unwrap());
        let wire_len = u64::from_le_bytes(raw[16..24].try_into().unwrap());
        let serialized_len = u64::from_le_bytes(raw[24..32].try_into().unwrap());
        let count = u64::from_le_bytes(raw[32..40].try_into().unwrap());
        let crc_expect = u32::from_le_bytes(raw[40..44].try_into().unwrap());
        if wire_len > MAX_PAYLOAD {
            return Err(DeferError::Wire(format!("payload {wire_len} exceeds cap")));
        }
        Ok(Header {
            msg_type,
            frame,
            wire_len,
            serialized_len,
            count,
            batch,
            crc_expect,
            raw: *raw,
        })
    }

    /// Verify the CRC over header + payload and assemble the message.
    ///
    /// Single-pass ingest (§Perf): when the payload is a structurally
    /// valid chunk container, the message CRC is reconstituted from the
    /// container's *stored* per-chunk CRCs via [`crc32::combine`] — only
    /// the header and the container's metadata prefix are actually
    /// swept here. The chunk bodies are then CRC-verified exactly once,
    /// by `serial::chunked::decode_frame`'s chunk walk (which reports
    /// corruption by index, the NACKable form), instead of twice. A
    /// corrupted metadata prefix or stored CRC still fails right here;
    /// any other payload shape takes the classic full sweep.
    pub fn into_message(self, payload: Vec<u8>) -> Result<Message> {
        let chunked_crc = if matches!(
            self.msg_type,
            MessageType::Data | MessageType::ResultMsg | MessageType::Weights
        ) {
            crate::serial::chunked::container_layout(&payload).map(|layout| {
                let prefix = crc32::finish(crc32::update(
                    crc32::update(crc32::init(), &self.raw[0..40]),
                    &payload[..layout.prefix_len],
                ));
                (0..layout.n_chunks).fold(prefix, |acc, i| {
                    let (crc, len) = crate::serial::chunked::chunk_crc_len(&payload, i);
                    crc32::combine(acc, crc, len)
                })
            })
        } else {
            None
        };
        let crc_actual = chunked_crc.unwrap_or_else(|| {
            crc32::finish(crc32::update(
                crc32::update(crc32::init(), &self.raw[0..40]),
                &payload,
            ))
        });
        if crc_actual != self.crc_expect {
            return Err(DeferError::Wire(format!(
                "crc mismatch: {crc_actual:#x} != {:#x}",
                self.crc_expect
            )));
        }
        Ok(Message {
            msg_type: self.msg_type,
            frame: self.frame,
            serialized_len: self.serialized_len,
            count: self.count,
            batch: self.batch,
            payload,
        })
    }
}

/// Read one message written by [`write_message`]. Validates magic, type,
/// size sanity and CRC.
pub fn read_message(r: &mut impl Read, counter: &ByteCounter) -> Result<Message> {
    read_message_pooled(r, counter, None)
}

/// [`read_message`] drawing the payload buffer from `pool` when given —
/// the allocation-hygiene variant for per-frame traffic. The consumer
/// should hand `Message::payload` back to the same pool once decoded,
/// closing the recycling loop (the old path paid a fresh
/// `vec![0u8; wire_len]` per frame).
pub fn read_message_pooled(
    r: &mut impl Read,
    counter: &ByteCounter,
    pool: Option<&crate::util::bufpool::BufPool>,
) -> Result<Message> {
    let mut header = [0u8; HEADER_SIZE];
    r.read_exact(&mut header)?;
    counter.add(HEADER_SIZE as u64);
    let h = Header::parse(&header)?;
    let wire_len = h.wire_len;
    let mut payload = match pool {
        Some(p) => p.take_len(wire_len as usize),
        None => vec![0u8; wire_len as usize],
    };
    r.read_exact(&mut payload)?;
    counter.add(wire_len);
    h.into_message(payload)
}

/// Incremental message parser for nonblocking sockets: feed it whatever
/// bytes are available and it resumes mid-header or mid-payload across
/// readiness windows. The reactor's ingress machines drive one assembler
/// per TCP connection; validation is [`Header::parse`] +
/// [`Header::into_message`], i.e. exactly the blocking reader's.
pub struct FrameAssembler {
    state: AsmState,
}

enum AsmState {
    Header {
        buf: [u8; HEADER_SIZE],
        filled: usize,
    },
    Payload {
        header: Header,
        buf: Vec<u8>,
        filled: usize,
    },
    /// Transient marker while ownership moves between states.
    Swapping,
}

impl Default for FrameAssembler {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler {
            state: AsmState::Header {
                buf: [0u8; HEADER_SIZE],
                filled: 0,
            },
        }
    }

    /// True when no bytes of the next message have arrived yet — i.e. a
    /// peer closing now is a mid-stream EOF only if this is false.
    pub fn at_boundary(&self) -> bool {
        matches!(self.state, AsmState::Header { filled: 0, .. })
    }

    /// Pull bytes from `read` (a nonblocking source: returns how many
    /// bytes it wrote into the slice) until a full message assembles,
    /// the source would block, or it errors.
    ///
    /// * `Ok(Some(msg))` — one complete, CRC-verified message.
    /// * `Ok(None)` — the source would block mid-message; call again on
    ///   the next readiness event (`WouldBlock` is absorbed here,
    ///   `Interrupted` is retried).
    /// * `Err(..)` — protocol violation, I/O error, or EOF (a peer that
    ///   closes mid-stream surfaces as `UnexpectedEof`; clean shutdown
    ///   in this protocol is an explicit `Shutdown` message, so EOF is
    ///   always an error for the data plane).
    pub fn poll<R>(
        &mut self,
        read: &mut R,
        pool: Option<&crate::util::bufpool::BufPool>,
    ) -> Result<Option<Message>>
    where
        R: FnMut(&mut [u8]) -> std::io::Result<usize>,
    {
        loop {
            match &mut self.state {
                AsmState::Header { buf, filled } => {
                    while *filled < HEADER_SIZE {
                        match read(&mut buf[*filled..]) {
                            Ok(0) => {
                                return Err(std::io::Error::from(
                                    std::io::ErrorKind::UnexpectedEof,
                                )
                                .into())
                            }
                            Ok(n) => *filled += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(None)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let header = Header::parse(buf)?;
                    let wire_len = header.wire_len as usize;
                    let payload = match pool {
                        Some(p) => p.take_len(wire_len),
                        None => vec![0u8; wire_len],
                    };
                    self.state = AsmState::Payload {
                        header,
                        buf: payload,
                        filled: 0,
                    };
                }
                AsmState::Payload { buf, filled, .. } => {
                    while *filled < buf.len() {
                        match read(&mut buf[*filled..]) {
                            Ok(0) => {
                                return Err(std::io::Error::from(
                                    std::io::ErrorKind::UnexpectedEof,
                                )
                                .into())
                            }
                            Ok(n) => *filled += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(None)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let state = std::mem::replace(&mut self.state, AsmState::Swapping);
                    let AsmState::Payload { header, buf, .. } = state else {
                        unreachable!()
                    };
                    self.state = AsmState::Header {
                        buf: [0u8; HEADER_SIZE],
                        filled: 0,
                    };
                    return Ok(Some(header.into_message(buf)?));
                }
                AsmState::Swapping => unreachable!("assembler observed mid-swap"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn round_trip(msg: &Message) -> Message {
        let mut buf = Vec::new();
        let link = Link::ideal();
        let tx = ByteCounter::new();
        write_message(&mut buf, msg, &link, &tx).unwrap();
        assert_eq!(tx.total(), msg.wire_size());
        let rx = ByteCounter::new();
        let got = read_message(&mut buf.as_slice(), &rx).unwrap();
        assert_eq!(rx.total(), msg.wire_size());
        got
    }

    #[test]
    fn control_message_round_trip() {
        let msg = Message::control(MessageType::Shutdown);
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn tensor_message_round_trip() {
        let mut rng = Rng::new(51);
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 1234,
            serialized_len: 999,
            count: 250,
            batch: 1,
            payload: rng.bytes(1000),
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn batched_message_round_trip() {
        let mut rng = Rng::new(53);
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 64,
            serialized_len: 4000,
            count: 1000,
            batch: 8,
            payload: rng.bytes(4000),
        };
        let got = round_trip(&msg);
        assert_eq!(got.batch, 8);
        assert_eq!(got, msg);
    }

    #[test]
    fn batch_one_is_byte_identical_to_legacy_wire_format() {
        // batch == 1 must write zeros in the former pad bytes — the
        // whole encoded stream is the pre-batching format, bit for bit.
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 7,
            serialized_len: 16,
            count: 4,
            batch: 1,
            payload: vec![1, 2, 3, 4],
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        assert_eq!(&buf[5..8], &[0u8, 0, 0], "pad bytes must stay zero");
    }

    #[test]
    fn zero_and_oversize_batch_rejected_before_write() {
        let mut msg = Message::control(MessageType::Data);
        msg.batch = 0;
        let mut buf = Vec::new();
        assert!(write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).is_err());
        msg.batch = MAX_BATCH + 1;
        assert!(write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).is_err());
        msg.batch = MAX_BATCH;
        assert!(write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).is_ok());
    }

    #[test]
    fn multi_chunk_payload() {
        let mut rng = Rng::new(52);
        // > 2 chunks of 512 kB
        let msg = Message {
            msg_type: MessageType::Weights,
            frame: 0,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: rng.bytes(CHUNK_SIZE * 2 + 777),
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn chunk_control_round_trip() {
        // NACKs are inline WireFrames now; their wire image must parse
        // back through the ordinary reader.
        let nack = chunk_nack(42, 7);
        let bytes = nack.to_wire_bytes();
        let got = read_message(&mut bytes.as_slice(), &ByteCounter::new()).unwrap();
        assert_eq!(got.frame, 42);
        assert_eq!(got.msg_type, MessageType::ChunkNack);
        let (idx, rest) = parse_chunk_control(&got).unwrap();
        assert_eq!((idx, rest.len()), (7, 0));

        let retry = chunk_retry(42, 7, &[9, 8, 7, 6, 5]);
        let got = round_trip(&retry);
        let (idx, bytes) = parse_chunk_control(&got).unwrap();
        assert_eq!(idx, 7);
        assert_eq!(bytes, &[9, 8, 7, 6, 5]);
    }

    #[test]
    fn crc_combine_matches_direct_concatenation() {
        let mut rng = Rng::new(61);
        for (la, lb) in [(0usize, 0usize), (1, 1), (9, 0), (0, 9), (100, 1000), (4096, 7)] {
            let a = rng.bytes(la);
            let b = rng.bytes(lb);
            let mut joined = a.clone();
            joined.extend_from_slice(&b);
            assert_eq!(
                crc32::combine(crc32::crc32(&a), crc32::crc32(&b), b.len() as u64),
                crc32::crc32(&joined),
                "la={la} lb={lb}"
            );
        }
    }

    #[test]
    fn wireframe_bytes_identical_to_write_message() {
        let mut rng = Rng::new(62);
        for payload_len in [0usize, 4, 1000, CHUNK_SIZE + 5] {
            let msg = Message {
                msg_type: MessageType::Data,
                frame: 17,
                serialized_len: payload_len as u64,
                count: (payload_len / 4) as u64,
                batch: 3,
                payload: rng.bytes(payload_len),
            };
            let mut legacy = Vec::new();
            let tx = ByteCounter::new();
            write_message(&mut legacy, &msg, &Link::ideal(), &tx).unwrap();
            let wf = WireFrame::new(
                msg.msg_type,
                msg.frame,
                msg.batch,
                msg.serialized_len,
                msg.count,
                SharedPayload::from_vec(msg.payload.clone(), None),
            )
            .unwrap();
            assert_eq!(wf.to_wire_bytes(), legacy, "payload_len={payload_len}");
            // charge() must account the same byte total write_message did.
            let charged = ByteCounter::new();
            wf.charge(&Link::ideal(), &charged);
            assert_eq!(charged.total(), tx.total());
            // A clone shares, not copies; into_message on the last
            // reference hands the buffer back untouched.
            let clone = wf.clone();
            drop(wf);
            assert_eq!(clone.into_message().payload, msg.payload);
        }
    }

    #[test]
    fn wireframe_accessors_match_header_fields() {
        let wf = WireFrame::new(
            MessageType::ResultMsg,
            99,
            5,
            1234,
            300,
            SharedPayload::inline(&[1, 2, 3]),
        )
        .unwrap();
        assert_eq!(wf.msg_type(), MessageType::ResultMsg);
        assert_eq!(wf.frame(), 99);
        assert_eq!(wf.batch(), 5);
        assert_eq!(wf.serialized_len(), 1234);
        assert_eq!(wf.count(), 300);
        assert_eq!(wf.payload_bytes(), &[1, 2, 3]);
        assert_eq!(wf.wire_size(), HEADER_SIZE as u64 + 3);
        assert!(WireFrame::new(
            MessageType::Data,
            0,
            0,
            0,
            0,
            SharedPayload::inline(&[])
        )
        .is_err());
    }

    #[test]
    fn single_pass_ingest_accepts_containers_and_rejects_bad_prefixes() {
        use crate::serial::chunked::{CONTAINER_HEADER, PER_CHUNK_HEADER};
        // A hand-built 2-chunk container with correct stored CRCs.
        let bodies: [&[u8]; 2] = [&[10, 20, 30], &[40, 50]];
        let mut payload = Vec::new();
        payload.extend_from_slice(&crate::serial::chunked::CHUNK_MAGIC.to_le_bytes());
        payload.extend_from_slice(&2u32.to_le_bytes());
        payload.extend_from_slice(&4u32.to_le_bytes());
        for b in bodies {
            payload.extend_from_slice(&(b.len() as u32).to_le_bytes());
            payload.extend_from_slice(&(b.len() as u32).to_le_bytes());
            payload.extend_from_slice(&crc32::crc32(b).to_le_bytes());
        }
        for b in bodies {
            payload.extend_from_slice(b);
        }
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 5,
            serialized_len: 5,
            count: 5,
            batch: 1,
            payload,
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        // Clean container: the combine fast path must accept it.
        let got = read_message(&mut buf.as_slice(), &ByteCounter::new()).unwrap();
        assert_eq!(got, msg);
        // Flip a byte in the metadata prefix (a stored chunk CRC): the
        // fast path itself must reject at ingest.
        let mut bad = buf.clone();
        let crc_off = HEADER_SIZE + CONTAINER_HEADER + 8;
        bad[crc_off] ^= 0xFF;
        assert!(read_message(&mut bad.as_slice(), &ByteCounter::new()).is_err());
        // Flip a chunk *body* byte: ingest defers to the decode walk,
        // which reports it as a NACKable CorruptChunk — verify the walk
        // still sees the stored-CRC mismatch.
        let mut corrupt_body = buf.clone();
        let body_off = HEADER_SIZE + CONTAINER_HEADER + 2 * PER_CHUNK_HEADER;
        corrupt_body[body_off] ^= 0xFF;
        let got = read_message(&mut corrupt_body.as_slice(), &ByteCounter::new()).unwrap();
        let span = crate::serial::chunked::chunk_payload_span(&got.payload, 0).unwrap();
        let (stored, _) = crate::serial::chunked::chunk_crc_len(&got.payload, 0);
        assert_ne!(crc32::crc32(&got.payload[span]), stored);
    }

    #[test]
    fn chunk_control_rejects_wrong_type_and_short_payload() {
        let msg = Message::control(MessageType::Data);
        assert!(parse_chunk_control(&msg).is_err());
        let mut short = Message::control(MessageType::ChunkNack);
        short.payload = vec![1, 2];
        assert!(parse_chunk_control(&short).is_err());
    }

    #[test]
    fn corrupt_payload_detected() {
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 1,
            serialized_len: 8,
            count: 2,
            batch: 1,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        let n = buf.len();
        buf[n - 3] ^= 0xFF; // flip payload byte
        assert!(read_message(&mut buf.as_slice(), &ByteCounter::new()).is_err());
    }

    #[test]
    fn bad_magic_and_type_detected() {
        let msg = Message::control(MessageType::Ready);
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        let mut bad = buf.clone();
        bad[0] ^= 1;
        assert!(read_message(&mut bad.as_slice(), &ByteCounter::new()).is_err());
        let mut bad_type = buf;
        bad_type[4] = 77;
        assert!(read_message(&mut bad_type.as_slice(), &ByteCounter::new()).is_err());
    }

    #[test]
    fn truncated_stream_detected() {
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 1,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: vec![9; 100],
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(read_message(&mut buf.as_slice(), &ByteCounter::new()).is_err());
    }

    #[test]
    fn oversize_header_rejected_before_alloc() {
        let msg = Message::control(MessageType::Data);
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        // Forge a huge length field.
        buf[16..24].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(read_message(&mut buf.as_slice(), &ByteCounter::new()).is_err());
    }

    /// A nonblocking byte source that hands out `stream` in fixed-size
    /// dribbles, reporting `WouldBlock` between every delivery — the
    /// worst-case readiness pattern a real socket can produce.
    struct Dribble {
        stream: Vec<u8>,
        pos: usize,
        step: usize,
        /// Alternate deliveries with WouldBlock.
        starve: bool,
        parity: bool,
    }

    impl Dribble {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.starve {
                self.parity = !self.parity;
                if self.parity {
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
            }
            let n = self.step.min(out.len()).min(self.stream.len() - self.pos);
            out[..n].copy_from_slice(&self.stream[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn assembler_resumes_across_arbitrary_split_points() {
        let mut rng = Rng::new(59);
        let msgs: Vec<Message> = (0..4)
            .map(|i| Message {
                msg_type: MessageType::Data,
                frame: i,
                serialized_len: 100 + i,
                count: 25,
                batch: 1 + i as u32,
                payload: rng.bytes(100 + i as usize * 37),
            })
            .collect();
        let mut stream = Vec::new();
        for m in &msgs {
            write_message(&mut stream, m, &Link::ideal(), &ByteCounter::new()).unwrap();
        }
        // Every dribble size, including pathological 1-byte deliveries,
        // with and without interleaved WouldBlock starvation.
        for step in [1usize, 3, 7, HEADER_SIZE, 1000] {
            for starve in [false, true] {
                let mut src = Dribble {
                    stream: stream.clone(),
                    pos: 0,
                    step,
                    starve,
                    parity: false,
                };
                let mut asm = FrameAssembler::new();
                let mut got = Vec::new();
                while got.len() < msgs.len() {
                    match asm.poll(&mut |buf: &mut [u8]| src.read(buf), None).unwrap() {
                        Some(m) => got.push(m),
                        None => continue, // starved; "readiness" loops us back
                    }
                }
                assert_eq!(got, msgs, "step={step} starve={starve}");
                assert!(asm.at_boundary());
            }
        }
    }

    #[test]
    fn assembler_reports_eof_and_corruption_like_the_blocking_reader() {
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 3,
            serialized_len: 8,
            count: 2,
            batch: 1,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        };
        let mut stream = Vec::new();
        write_message(&mut stream, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();

        // Truncated mid-payload: EOF must surface as an error.
        let mut cut = stream.clone();
        cut.truncate(cut.len() - 3);
        let mut pos = 0usize;
        let mut asm = FrameAssembler::new();
        let err = asm
            .poll(
                &mut |buf: &mut [u8]| {
                    let n = buf.len().min(cut.len() - pos);
                    buf[..n].copy_from_slice(&cut[pos..pos + n]);
                    pos += n;
                    Ok(n)
                },
                None,
            )
            .unwrap_err();
        assert!(format!("{err}").contains("io"), "{err}");
        assert!(!asm.at_boundary(), "EOF hit mid-message");

        // Flipped payload byte: same CRC error as read_message.
        let mut bad = stream.clone();
        let n = bad.len();
        bad[n - 2] ^= 0x10;
        let mut pos = 0usize;
        let mut asm = FrameAssembler::new();
        let err = asm
            .poll(
                &mut |buf: &mut [u8]| {
                    let take = buf.len().min(bad.len() - pos);
                    buf[..take].copy_from_slice(&bad[pos..pos + take]);
                    pos += take;
                    Ok(take)
                },
                None,
            )
            .unwrap_err();
        assert!(format!("{err}").contains("crc mismatch"), "{err}");
    }
}
