//! FLOPs-aware repartitioning: stage boundaries as a planning output.
//!
//! PR 2's placement planner ([`crate::placement`]) replicates the stage
//! boundaries it is handed; this module supplies the other half of the
//! DEFER authors' follow-up (arXiv 2210.12219, "Partitioning and
//! Placement of DNNs on Distributed Edge Devices"): *choosing* those
//! boundaries. It takes the finest-granularity partition set the
//! artifact registry knows ([`crate::model::finest_part_count`]), treats
//! every cut between adjacent partitions as optional, and jointly picks
//! cut points and per-stage replica counts to minimize the modeled
//! pipeline bottleneck under a total-worker budget. Fused runs of
//! partitions become [`crate::model::StageSpec`] stages: their FLOPs
//! sum, their inner activation boundaries never touch the network, and
//! their weight payloads concatenate into one configuration exchange.
//!
//! # Cost model
//!
//! Exactly [`crate::placement`]'s (same `transfer_secs` pricing, same
//! interior-link rule, same round-robin replica semantics):
//!
//! * a stage fusing parts `j..i` with `r` replicas serves a frame every
//!   `(flops(j..i) / f + egress(i)) / r` seconds, where `egress(i)` is
//!   the best interconnect candidate's transfer time for partition
//!   `i-1`'s output bytes;
//! * the dispatcher uplink is one shared, unreplicable link whose
//!   occupancy is a constant of the model input — no cut choice moves
//!   hop 0, so the search ignores it and the final placement pass
//!   reports it as the bottleneck when it gates;
//! * `f` is the *slowest* pooled device's FLOP rate — conservative for
//!   heterogeneous pools (the placement pass then assigns fast devices
//!   to heavy stages and re-evaluates exactly);
//! * codec time is charged through the shared
//!   [`crate::placement::CodecCost`]: a stage decodes its first
//!   partition's input and encodes its last partition's output, so
//!   fusing also elides the *codec* work of inner boundaries — under the
//!   pipelined runtime the per-replica busy time is
//!   `max(decode, compute, encode + egress)`, inline it is the sum.
//!
//! # Memory, and why it exists
//!
//! Under this cost model alone, one fully-fused stage replicated across
//! the whole budget weakly dominates every pipeline (a stage's service
//! is a max over per-replica means; fusing everything turns the max
//! into the mean). The reason DEFER pipelines at all is that edge
//! devices cannot hold the whole model: [`RepartitionProblem`] therefore
//! carries an optional per-device weight-residency cap
//! (`device_memory`), and a fused run whose summed `weights_bytes`
//! exceeds it is not a legal stage. With no cap the planner honestly
//! collapses toward few, wide stages — pass `--device-memory` to model
//! real devices.
//!
//! # Algorithm
//!
//! A dynamic program over `(partitions consumed, workers spent)`:
//! `dp[i][w]` is the least achievable max-stage-service covering the
//! first `i` partitions with at most `w` workers, with transitions over
//! the last stage's start `j` and replica count `r`. `O(n² · W²)` for
//! `n` fine partitions and budget `W` — both small. Ties break toward
//! the earliest split point and the fewest replicas, and the final
//! worker count is the smallest that achieves the optimum, so output is
//! canonical. The chosen cuts are then re-priced by
//! [`crate::placement::plan`] against the *real* device pool, which
//! assigns devices, picks hop links, replicates and trims — the emitted
//! [`PlacementPlan`] (and its `Topology`) is what the chain runner
//! deploys.
//!
//! Everything here is pure and deterministic — no RNG, no clocks, no
//! artifact reads — so `render()` is byte-identical across runs and
//! goldens-testable from synthetic partition costs alone.

use crate::config::DeferConfig;
use crate::error::{DeferError, Result};
use crate::model::PartitionPlan;
use crate::netem::LinkSpec;
use crate::placement::{
    self, best_link_for, transfer_secs, BatchCost, CodecCost, DeviceProfile, PlacementPlan,
    PlacementProblem, StageCost,
};
use crate::topology::Topology;

/// What the planner needs to know about one finest-granularity
/// partition — a [`StageCost`] plus the resident weight bytes that
/// drive the memory cap.
#[derive(Clone, Debug)]
pub struct PartCost {
    /// FLOPs to execute the partition once.
    pub flops: u64,
    /// Uncompressed activation bytes entering the partition.
    pub input_bytes: u64,
    /// Uncompressed activation bytes leaving the partition.
    pub output_bytes: u64,
    /// Resident weight bytes a hosting worker must hold.
    pub weights_bytes: u64,
}

/// A complete repartitioning problem: finest-granularity partition
/// costs, the device pool, the worker budget, the per-device memory
/// cap, and the link vocabulary.
#[derive(Clone, Debug)]
pub struct RepartitionProblem {
    pub parts: Vec<PartCost>,
    /// Devices available to host worker replicas.
    pub devices: Vec<DeviceProfile>,
    /// Max worker replicas across all stages (>= 1, <= devices).
    pub worker_budget: usize,
    /// Max summed `weights_bytes` one worker may host (a fused run
    /// exceeding this is not a legal stage). `None` = unlimited, under
    /// which the cost model favors few, wide stages — see module docs.
    pub device_memory: Option<u64>,
    /// The dispatcher's physical medium — always hop 0.
    pub uplink: LinkSpec,
    /// Candidate links for every later hop. Empty = uplink everywhere.
    pub interconnect: Vec<LinkSpec>,
    /// Codec service rates charged per frame, shared with
    /// [`crate::placement`] so both passes price codec time identically
    /// ([`CodecCost::ZERO`] = the pre-calibration model).
    pub codec: CodecCost,
    /// Price the legacy junction-relay data plane (see
    /// [`PlacementProblem::relay_junctions`]). The DP search itself
    /// stays relay-blind — relay pricing depends on the replica counts
    /// of *both* boundary sides, which the per-stage transitions do not
    /// see — but the final [`crate::placement::plan`] re-pricing of the
    /// chosen cuts charges the relay hop exactly, so the emitted plan
    /// (and its render) is honest about the legacy wiring.
    pub relay_junctions: bool,
    /// Micro-batching terms, shared with [`crate::placement`] so both
    /// passes price batches identically ([`BatchCost::ZERO`] = batching
    /// not priced). Like relay pricing, the DP search itself stays
    /// batch-blind — the amortized charge shifts every candidate
    /// stage's busy time by the same `fixed / B`, which cannot reorder
    /// cut choices — but the final [`crate::placement::plan`] re-pricing
    /// of the chosen cuts searches batch sizes exactly, so the emitted
    /// plan (and its render) carries the throughput-optimal `B`.
    pub batch: BatchCost,
}

impl RepartitionProblem {
    /// Build the problem a [`DeferConfig`] + finest partition plan
    /// describe. Links and the device pool are derived exactly as for
    /// [`PlacementProblem::from_config`].
    pub fn from_config(cfg: &DeferConfig, plan: &PartitionPlan) -> Result<RepartitionProblem> {
        let parts = plan
            .parts
            .iter()
            .map(|q| PartCost {
                flops: q.flops,
                input_bytes: q.input_bytes(),
                output_bytes: q.output_bytes(),
                weights_bytes: q.weights_bytes as u64,
            })
            .collect();
        Self::from_parts(cfg, parts)
    }

    /// Build from explicit partition costs (the `defer plan --synthetic`
    /// path: no artifacts touched, everything else from the config).
    pub fn from_parts(cfg: &DeferConfig, parts: Vec<PartCost>) -> Result<RepartitionProblem> {
        let (uplink, interconnect) = placement::links_from_config(cfg);
        let (devices, worker_budget) = placement::device_pool_from_config(cfg)?;
        Ok(RepartitionProblem {
            parts,
            devices,
            worker_budget,
            device_memory: if cfg.device_memory > 0 {
                Some(cfg.device_memory)
            } else {
                None
            },
            uplink,
            interconnect,
            codec: placement::codec_cost_from_config(cfg),
            relay_junctions: cfg.relay_junctions,
            batch: placement::batch_cost_from_config(cfg),
        })
    }
}

/// One fused stage of the chosen plan, with its fusion accounting.
#[derive(Clone, Debug)]
pub struct FusedStage {
    /// First fused partition index (inclusive).
    pub first_part: usize,
    /// Last fused partition index (inclusive).
    pub last_part: usize,
    /// Summed FLOPs of the fused run.
    pub flops: u64,
    /// Summed resident weight bytes (what the memory cap constrains).
    pub weights_bytes: u64,
    /// Activation bytes of inner boundaries elided from the network.
    pub elided_bytes: u64,
}

impl FusedStage {
    /// Stable label: `p2` for a single partition, `p0..p1` for a run.
    pub fn label(&self) -> String {
        if self.first_part == self.last_part {
            format!("p{}", self.first_part)
        } else {
            format!("p{}..p{}", self.first_part, self.last_part)
        }
    }
}

/// The joint planner's output: cut points, fused-stage accounting, and
/// the placement (replicas, devices, links, predicted throughput) over
/// those fused stages.
#[derive(Clone, Debug)]
pub struct RepartitionPlan {
    /// `num_stages + 1` cut points; stage `s` fuses partitions
    /// `cuts[s]..cuts[s+1]` (feed to [`PartitionPlan::fuse`]).
    pub cuts: Vec<usize>,
    /// Number of finest-granularity partitions (== `cuts.last()`).
    pub part_count: usize,
    /// Per-stage fusion accounting, stage order.
    pub stages: Vec<FusedStage>,
    /// Placement over the fused stages.
    pub placement: PlacementPlan,
}

impl RepartitionPlan {
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total worker replicas the joint plan places.
    pub fn num_workers(&self) -> usize {
        self.placement.num_workers()
    }

    /// Replica counts per fused stage.
    pub fn replica_counts(&self) -> Vec<usize> {
        self.placement.replica_counts()
    }

    /// Modeled steady-state frames/second.
    pub fn predicted_throughput(&self) -> f64 {
        self.placement.predicted_throughput
    }

    /// The [`Topology`] over the fused stages — consumed by the chain
    /// runner exactly like a hand-written one.
    pub fn topology(&self) -> Result<Topology> {
        self.placement.topology()
    }

    /// Stable human-readable rendering (also the goldens surface: the
    /// planner is deterministic, so this string is byte-identical
    /// across runs on the same problem). The placement section is
    /// [`PlacementPlan::render`] verbatim.
    pub fn render(&self) -> String {
        let mut out = format!(
            "repartition plan: {} partition(s) fused into {} stage(s), cuts {:?}\n",
            self.part_count,
            self.num_stages(),
            self.cuts
        );
        for (i, st) in self.stages.iter().enumerate() {
            out.push_str(&format!(
                "  stage {i} = {}: {:.3} MFLOP, weights {} B, elided boundary {} B\n",
                st.label(),
                st.flops as f64 / 1e6,
                st.weights_bytes,
                st.elided_bytes
            ));
        }
        out.push_str(&self.placement.render());
        out
    }
}

const EPS: f64 = 1e-12;

/// Jointly choose cut points and replica counts for `p` (see module
/// docs). Deterministic: same problem, same plan, byte-identical
/// rendering.
pub fn plan(p: &RepartitionProblem) -> Result<RepartitionPlan> {
    let n = p.parts.len();
    if n == 0 {
        return Err(DeferError::Config(
            "repartitioning needs at least one partition".into(),
        ));
    }
    if p.worker_budget == 0 {
        return Err(DeferError::Config(
            "workers budget 0 cannot host any stage".into(),
        ));
    }
    if p.devices.len() < p.worker_budget {
        return Err(DeferError::Config(format!(
            "workers budget {} exceeds the {} available devices",
            p.worker_budget,
            p.devices.len()
        )));
    }
    if let Some(d) = p.devices.iter().find(|d| !(d.mflops > 0.0)) {
        return Err(DeferError::Config(format!(
            "device {:?}: mflops must be > 0, got {}",
            d.name, d.mflops
        )));
    }
    if let Some(cap) = p.device_memory {
        if let Some((i, q)) = p
            .parts
            .iter()
            .enumerate()
            .find(|(_, q)| q.weights_bytes > cap)
        {
            return Err(DeferError::Config(format!(
                "device_memory {cap} B cannot hold partition p{i} ({} B of weights) — \
                 no cut placement can help",
                q.weights_bytes
            )));
        }
    }

    // Conservative homogeneous rate for the search: the slowest pooled
    // device (every device can sustain the plan; placement re-prices
    // the chosen cuts against the real pool below).
    let f_dp = p
        .devices
        .iter()
        .map(|d| d.mflops * 1e6)
        .fold(f64::INFINITY, f64::min);
    let candidates: &[LinkSpec] = if p.interconnect.is_empty() {
        std::slice::from_ref(&p.uplink)
    } else {
        &p.interconnect
    };
    // egress[i-1]: modeled egress seconds for a stage ending after
    // partition i-1 (interior-link rule shared with placement).
    let egress: Vec<f64> = p
        .parts
        .iter()
        .map(|q| {
            transfer_secs(&best_link_for(candidates, q.output_bytes), q.output_bytes)
        })
        .collect();
    // Codec terms (zero under the pre-calibration model): a stage
    // starting at partition j decodes parts[j]'s input; one ending after
    // partition i-1 encodes parts[i-1]'s output. Same pricing as
    // placement::plan, which re-evaluates the chosen cuts below.
    let dec_in: Vec<f64> = p
        .parts
        .iter()
        .map(|q| p.codec.dec_secs_per_byte * q.input_bytes as f64)
        .collect();
    let enc_out: Vec<f64> = p
        .parts
        .iter()
        .map(|q| p.codec.enc_secs_per_byte * q.output_bytes as f64)
        .collect();
    let charges_codec =
        p.codec.enc_secs_per_byte > 0.0 || p.codec.dec_secs_per_byte > 0.0;
    // Prefix sums for O(1) run accounting.
    let mut flops_pre = vec![0f64; n + 1];
    let mut weights_pre = vec![0u64; n + 1];
    for (i, q) in p.parts.iter().enumerate() {
        flops_pre[i + 1] = flops_pre[i] + q.flops as f64;
        weights_pre[i + 1] = weights_pre[i] + q.weights_bytes;
    }

    // dp[i][w]: least max-stage-service covering parts[0..i] with at
    // most w workers; parent = (run start j, replicas r) of the last
    // stage. Ties keep the first (j, r) found: earliest split, fewest
    // replicas.
    let wb = p.worker_budget;
    let cols = wb + 1;
    let mut dp = vec![f64::INFINITY; (n + 1) * cols];
    let mut parent = vec![(usize::MAX, 0usize); (n + 1) * cols];
    // Zero parts cost nothing whatever the budget (row i = 0).
    for slot in dp.iter_mut().take(cols) {
        *slot = 0.0;
    }
    for i in 1..=n {
        for w in 1..=wb {
            let mut best = f64::INFINITY;
            let mut arg = (usize::MAX, 0usize);
            for j in 0..i {
                if let Some(cap) = p.device_memory {
                    if weights_pre[i] - weights_pre[j] > cap {
                        continue;
                    }
                }
                let compute = (flops_pre[i] - flops_pre[j]) / f_dp;
                let base = if p.codec.pipelined && charges_codec {
                    // Pipelined phases overlap; the slowest gates.
                    dec_in[j].max(compute).max(enc_out[i - 1] + egress[i - 1])
                } else {
                    dec_in[j] + compute + enc_out[i - 1] + egress[i - 1]
                };
                for r in 1..=w {
                    let prev = dp[j * cols + (w - r)];
                    if !prev.is_finite() {
                        continue;
                    }
                    let gate = prev.max(base / r as f64);
                    if gate + EPS < best {
                        best = gate;
                        arg = (j, r);
                    }
                }
            }
            dp[i * cols + w] = best;
            parent[i * cols + w] = arg;
        }
    }
    if !dp[n * cols + wb].is_finite() {
        return Err(DeferError::Config(format!(
            "worker budget {wb} cannot cover the {n}-partition model under \
             device_memory {:?} B (more stages are forced than workers allowed)",
            p.device_memory
        )));
    }

    // Canonical worker count: the smallest that achieves the optimum.
    let optimum = dp[n * cols + wb];
    let w_star = (1..=wb)
        .find(|&w| dp[n * cols + w] <= optimum + EPS)
        .expect("budget column is feasible");

    // Reconstruct cut points.
    let mut cuts = vec![n];
    let (mut i, mut w) = (n, w_star);
    while i > 0 {
        let (j, r) = parent[i * cols + w];
        debug_assert!(j != usize::MAX && r >= 1);
        cuts.push(j);
        w -= r;
        i = j;
    }
    cuts.reverse();

    // Fusion accounting + placement over the fused stages against the
    // real (possibly heterogeneous) pool.
    let mut stages = Vec::with_capacity(cuts.len() - 1);
    let mut fused_costs = Vec::with_capacity(cuts.len() - 1);
    for c in cuts.windows(2) {
        let flops: u64 = p.parts[c[0]..c[1]].iter().map(|q| q.flops).sum();
        stages.push(FusedStage {
            first_part: c[0],
            last_part: c[1] - 1,
            flops,
            weights_bytes: weights_pre[c[1]] - weights_pre[c[0]],
            elided_bytes: p.parts[c[0]..c[1] - 1].iter().map(|q| q.output_bytes).sum(),
        });
        fused_costs.push(StageCost {
            flops,
            input_bytes: p.parts[c[0]].input_bytes,
            output_bytes: p.parts[c[1] - 1].output_bytes,
        });
    }
    let placement = placement::plan(&PlacementProblem {
        stages: fused_costs,
        devices: p.devices.clone(),
        worker_budget: p.worker_budget,
        uplink: p.uplink,
        interconnect: p.interconnect.clone(),
        codec: p.codec,
        relay_junctions: p.relay_junctions,
        batch: p.batch,
    })?;

    Ok(RepartitionPlan {
        cuts,
        part_count: n,
        stages,
        placement,
    })
}

/// Convenience: build the problem from config + finest plan, then plan.
pub fn plan_from_config(cfg: &DeferConfig, plan_: &PartitionPlan) -> Result<RepartitionPlan> {
    plan(&RepartitionProblem::from_config(cfg, plan_)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn homogeneous(n: usize, mflops: f64) -> Vec<DeviceProfile> {
        (0..n)
            .map(|i| DeviceProfile {
                name: format!("edge{i}"),
                mflops,
            })
            .collect()
    }

    fn part(flops: u64, input_bytes: u64, output_bytes: u64, weights_bytes: u64) -> PartCost {
        PartCost {
            flops,
            input_bytes,
            output_bytes,
            weights_bytes,
        }
    }

    fn problem(parts: Vec<PartCost>, budget: usize, memory: Option<u64>) -> RepartitionProblem {
        RepartitionProblem {
            parts,
            devices: homogeneous(budget, 100.0),
            worker_budget: budget,
            device_memory: memory,
            uplink: LinkSpec::wifi(),
            interconnect: vec![LinkSpec::gigabit_lan()],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        }
    }

    #[test]
    fn no_memory_cap_collapses_to_one_wide_stage() {
        // Documented degenerate optimum of the cost model: max over
        // per-replica means is minimized by fusing everything and
        // replicating across the whole budget.
        let p = problem(
            vec![
                part(100_000_000, 4_096, 4_096, 1_000),
                part(300_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
            ],
            4,
            None,
        );
        let rp = plan(&p).unwrap();
        assert_eq!(rp.cuts, vec![0, 3]);
        assert_eq!(rp.replica_counts(), vec![4]);
        assert_eq!(rp.stages[0].flops, 500_000_000);
        assert_eq!(rp.stages[0].weights_bytes, 3_000);
        assert_eq!(rp.stages[0].elided_bytes, 8_192);
    }

    #[test]
    fn memory_cap_forces_balanced_cuts() {
        // Cap of 2 partitions' weights per worker: the 4-partition model
        // must split into >= 2 stages; balanced [0,2,4] beats lopsided.
        let p = problem(
            vec![
                part(100_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
            ],
            4,
            Some(2_000),
        );
        let rp = plan(&p).unwrap();
        assert_eq!(rp.cuts, vec![0, 2, 4]);
        assert_eq!(rp.replica_counts(), vec![2, 2]);
        // Two partitions at 1 s each, fused: 2 s / 2 replicas = ~1 s gate.
        assert!((rp.predicted_throughput() - 1.0).abs() < 0.01);
    }

    #[test]
    fn joint_choice_beats_minmax_balance_when_budget_is_lopsided() {
        // Parts [4, 1, 1] (x 1e8 FLOPs) with one-part-per-worker memory:
        // with budget 4 the joint plan gives the heavy singleton stage
        // two replicas and fuses nothing (cap forbids fusing), landing
        // on cuts [0,1,2,3] with replicas [2,1,1].
        let p = problem(
            vec![
                part(400_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
            ],
            4,
            Some(1_000),
        );
        let rp = plan(&p).unwrap();
        assert_eq!(rp.cuts, vec![0, 1, 2, 3]);
        assert_eq!(rp.replica_counts(), vec![2, 1, 1]);
    }

    #[test]
    fn budget_below_forced_stage_count_is_rejected() {
        let p = problem(
            vec![
                part(100_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
                part(100_000_000, 4_096, 4_096, 1_000),
            ],
            1,
            Some(1_000),
        );
        let err = plan(&p).unwrap_err();
        assert!(format!("{err}").contains("worker budget"), "{err}");
    }

    #[test]
    fn oversized_partition_is_named() {
        let p = problem(vec![part(1, 1, 1, 5_000)], 1, Some(1_000));
        let err = plan(&p).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("p0") && msg.contains("5000"), "{msg}");
    }

    #[test]
    fn codec_charge_lowers_predicted_throughput() {
        // Same cuts, slower model: the codec term must make every plan
        // honest about serialization cost (ROADMAP item (c)).
        let parts = vec![
            part(100_000_000, 400_000, 400_000, 1_000),
            part(100_000_000, 400_000, 400_000, 1_000),
        ];
        let mut with = problem(parts.clone(), 2, Some(1_000));
        with.codec = CodecCost::from_gbps(0.1, false);
        let without = plan(&problem(parts, 2, Some(1_000))).unwrap();
        let with = plan(&with).unwrap();
        assert_eq!(with.cuts, without.cuts);
        assert!(with.predicted_throughput() < without.predicted_throughput());
        assert!(with.render().contains("codec"), "{}", with.render());
    }

    #[test]
    fn deterministic_render() {
        let mk = || {
            problem(
                vec![
                    part(100_000_000, 12_288, 65_536, 4_000),
                    part(300_000_000, 65_536, 65_536, 4_000),
                    part(100_000_000, 65_536, 4_096, 4_000),
                ],
                4,
                Some(8_000),
            )
        };
        let first = plan(&mk()).unwrap();
        for _ in 0..3 {
            assert_eq!(first.render(), plan(&mk()).unwrap().render());
        }
    }
}
