//! Deterministic PRNG (splitmix64 + xoshiro256**) for tests, benches and
//! the property-test harness. No external `rand` crate is available in the
//! offline environment, so this is the crate's randomness substrate.

/// splitmix64 — used for seeding and as a simple standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded generator; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let l = m as u64;
            if l >= bound || l >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard-normal-ish f32 via sum of uniforms (Irwin–Hall, k=12).
    /// Adequate for synthetic tensors; exact normality is irrelevant here.
    pub fn normal_f32(&mut self) -> f32 {
        let mut acc = 0.0f32;
        for _ in 0..12 {
            acc += self.f32();
        }
        acc - 6.0
    }

    /// Vector of synthetic activations.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Random bytes (for codec fuzzing).
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let v = self.next_u64().to_le_bytes();
            let take = (n - out.len()).min(8);
            out.extend_from_slice(&v[..take]);
        }
        out
    }

    /// Compressible byte stream: runs + repeated motifs + noise, used to
    /// exercise LZ4 match finding.
    pub fn compressible_bytes(&mut self, n: usize) -> Vec<u8> {
        let motif: Vec<u8> = (0..self.range(4, 64)).map(|_| self.next_u64() as u8).collect();
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.below(3) {
                0 => {
                    let b = self.next_u64() as u8;
                    let run = self.range(1, 128).min(n - out.len());
                    out.extend(std::iter::repeat(b).take(run));
                }
                1 => {
                    let take = motif.len().min(n - out.len());
                    out.extend_from_slice(&motif[..take]);
                }
                _ => {
                    let run = self.range(1, 32).min(n - out.len());
                    for _ in 0..run {
                        out.push(self.next_u64() as u8);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(1);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..50 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(3);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn bytes_len_exact() {
        let mut r = Rng::new(4);
        for n in [0, 1, 7, 8, 9, 1000] {
            assert_eq!(r.bytes(n).len(), n);
            assert_eq!(r.compressible_bytes(n.max(1)).len(), n.max(1));
        }
    }
}
