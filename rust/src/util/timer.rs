//! Scoped timers feeding the overhead accounting (paper §IV "Overhead":
//! time spent formatting data to be sent over the network).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotonically accumulating nanosecond counter, shareable across threads.
#[derive(Clone, Default, Debug)]
pub struct SharedTimer {
    nanos: Arc<AtomicU64>,
}

impl SharedTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure, accumulating its duration.
    #[inline]
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    /// Add an externally measured duration.
    #[inline]
    pub fn add(&self, d: std::time::Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn total(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let t = SharedTimer::new();
        t.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        t.add(std::time::Duration::from_millis(5));
        assert!(t.total() >= std::time::Duration::from_millis(10));
        t.reset();
        assert_eq!(t.total(), std::time::Duration::ZERO);
    }

    #[test]
    fn shared_across_clones() {
        let t = SharedTimer::new();
        let t2 = t.clone();
        t2.add(std::time::Duration::from_secs(1));
        assert_eq!(t.total(), std::time::Duration::from_secs(1));
    }
}
