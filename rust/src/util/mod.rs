//! Small shared utilities: seeded PRNG, byte formatting, timing helpers,
//! reusable buffer pools.

pub mod bufpool;
pub mod prng;
pub mod timer;

/// Format a byte count as a human-readable string (`12.3 MB`).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "kB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in engineering units (`1.23 ms`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(42), "42 B");
        assert_eq!(fmt_bytes(1500), "1.50 kB");
        assert_eq!(fmt_bytes(2_500_000), "2.50 MB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(std::time::Duration::from_millis(1500)), "1.500 s");
        assert_eq!(fmt_duration(std::time::Duration::from_micros(1500)), "1.500 ms");
        assert_eq!(fmt_duration(std::time::Duration::from_nanos(1500)), "1.5 us");
    }
}
