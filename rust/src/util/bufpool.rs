//! Reusable byte-buffer pool — allocation hygiene for the codec hot path.
//!
//! The chain moves MB-scale payloads every frame; before this pool every
//! frame paid a fresh `vec![0u8; wire_len]` in `wire::read_message` and a
//! fresh output `Vec` in `Codec::encode_f32s` / `Compression::compress`.
//! A [`BufPool`] recycles those buffers per connection (or per worker):
//! `take` hands back a previously returned buffer with its capacity
//! intact, `put` returns one after the consumer is done with it. The
//! pool is bounded so a burst cannot pin unbounded memory, and it is
//! `Mutex`-guarded — contention is negligible at frame granularity.
//!
//! Every `take*` records a hit (served from the free list) or a miss
//! (fresh allocation) — per pool and into the process-global
//! [`crate::metrics::zerocopy`] counters — so the zero-copy data plane
//! can prove steady-state traffic allocates nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::zerocopy;

/// A bounded pool of reusable `Vec<u8>` buffers.
#[derive(Debug, Default)]
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    /// Max buffers retained; extra `put`s drop the buffer instead.
    max: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BufPool {
    /// A pool retaining at most `max` free buffers (>= 1).
    pub fn new(max: usize) -> Self {
        BufPool {
            free: Mutex::new(Vec::new()),
            max: max.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Take an empty buffer (capacity from a previous `put` when
    /// available, freshly allocated otherwise).
    pub fn take(&self) -> Vec<u8> {
        match self.free.lock().unwrap().pop() {
            Some(buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                zerocopy::count_pool_hit();
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                zerocopy::count_pool_miss();
                Vec::new()
            }
        }
    }

    /// Take a buffer resized to `len` (zero-filled where not overwritten
    /// by a previous use — callers overwrite the whole range).
    pub fn take_len(&self, len: usize) -> Vec<u8> {
        let mut buf = self.take();
        buf.resize(len, 0);
        buf
    }

    /// Return a buffer for reuse. Contents are discarded.
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max {
            free.push(buf);
        }
    }

    /// Free buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// `take*` calls served from the free list.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// `take*` calls that allocated fresh.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_capacity() {
        let pool = BufPool::new(4);
        let mut a = pool.take_len(1000);
        a[999] = 7;
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.take_len(500);
        assert!(b.capacity() >= cap.min(500));
        assert_eq!(b.len(), 500);
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn bounded_retention() {
        let pool = BufPool::new(2);
        for _ in 0..5 {
            pool.put(Vec::with_capacity(64));
        }
        assert_eq!(pool.pooled(), 2);
        // Capacity-less buffers are not worth pooling.
        pool.take();
        pool.take();
        pool.put(Vec::new());
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn take_len_zeroes_new_range() {
        let pool = BufPool::new(1);
        let mut a = pool.take_len(8);
        a.iter_mut().for_each(|b| *b = 0xFF);
        pool.put(a);
        let b = pool.take_len(16);
        assert_eq!(b, vec![0u8; 16]);
    }

    #[test]
    fn counts_hits_and_misses() {
        let pool = BufPool::new(2);
        assert_eq!((pool.hits(), pool.misses()), (0, 0));
        let a = pool.take_len(32); // empty pool: miss
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        pool.put(a);
        let _b = pool.take(); // recycled: hit
        assert_eq!((pool.hits(), pool.misses()), (1, 1));
    }
}
