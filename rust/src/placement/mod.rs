//! Placement planning: a pure pass from stage costs to a deployment
//! [`Topology`].
//!
//! PR 1 made deployments declarative — [`Topology`] says how many worker
//! replicas serve each stage and which [`LinkSpec`] each hop uses — but
//! left *choosing* those numbers to hand-written `--replicas`/`--links`
//! flags. This module closes that loop in the spirit of the DEFER
//! authors' follow-up "Partitioning and Placement of DNNs on Distributed
//! Edge Devices" (arXiv 2210.12219): given what the partition plan
//! already knows (per-stage FLOPs and boundary activation sizes) and a
//! description of the hardware (per-device FLOP/s budgets, candidate
//! links), it models per-stage service time and emits the
//! throughput-maximizing topology under a total-worker budget.
//!
//! # Cost model
//!
//! The coordinator runs each worker replica as one thread that, per
//! frame, decodes, computes, encodes and then performs a *shaped* write
//! onto its own instance of the egress hop's link (see
//! `coordinator::chain`). A stage with `R` replicas is dealt frames
//! round-robin, so the planner models:
//!
//! * per-replica compute time `c_i = flops_i / f_min(devices_i)` — the
//!   round-robin deal hands every replica the same frame share, so the
//!   *slowest* device assigned to a stage gates it (a faster co-replica
//!   idles, it cannot steal work);
//! * per-replica egress time `e_i = bytes_out_i * 8 / bandwidth +
//!   latency + jitter/2` on the hop `i+1` link (each replica owns an
//!   independent physical link, so egress capacity scales with `R_i`);
//! * stage occupancy `s_i = (c_i + e_i) / R_i` — compute and egress
//!   serialize inside one replica thread;
//! * the dispatcher uplink (hop 0) is a *single* shared link whatever
//!   `R_0` is, so its occupancy `d = bytes_in_0 * 8 / bandwidth +
//!   latency + jitter/2` does not shrink with replication.
//!
//! Pipeline throughput is `1 / max(d, max_i s_i)`. Jitter enters as its
//! expectation so the plan stays deterministic.
//!
//! **Relay pricing.** The runtime's default data plane is worker-owned:
//! a replicated boundary is a direct replica-to-replica crossing, which
//! is exactly what the egress term above prices. Under the legacy
//! `--relay-junctions` wiring every frame crossing a replicated
//! *interior* boundary detours through a relay thread in the
//! coordinator process — on a real multi-host deployment that is a
//! second physical crossing of the hop (sender host → dispatcher host →
//! receiver host). With [`PlacementProblem::relay_junctions`] set the
//! model charges that hidden hop: interior-boundary egress doubles
//! whenever either side of the boundary is replicated. Hop 0 and the
//! return hop never double (the relay is co-located with the
//! dispatcher), and the default worker-owned model is byte-identical to
//! the pre-relay-pricing goldens.
//!
//! **Codec time** (ROADMAP item (c)) is charged through a [`CodecCost`]:
//! per frame a replica decodes its stage's input bytes and encodes its
//! output bytes at the configured secs/byte rates. With the runtime's
//! codec/compute software pipeline on (`codec_pipeline`, the default)
//! the phases overlap, so the per-replica busy time is
//! `max(decode, compute, encode + egress)`; with `--inline-codec` they
//! serialize and it is the sum. The rates come from `--codec-gbps`, a
//! live `--codec-measure` micro-benchmark, or the built-in per-codec
//! calibration table; `CodecCost::ZERO` (the `Default`) reproduces the
//! pre-calibration model exactly, keeping the plan goldens byte-stable.
//!
//! **Batch pricing** is charged through a [`BatchCost`]: every endpoint
//! pays a fixed per-message overhead (framing, syscalls, codec setup)
//! that at batch size `B` amortizes to `fixed / B` per frame — added to
//! each replica's busy time after the pipelined max (per-message work
//! does not overlap the phases it frames) and to the shared uplink. The
//! planner searches `B` in `1..=max_batch`, rejecting sizes whose
//! worst-case queueing wait — `(B-1)` gate periods — exceeds the latency
//! budget, and keeps the smallest `B` achieving the best feasible gate.
//! `BatchCost::ZERO` (the `Default`) keeps `B = 1` and reproduces the
//! pre-batching model exactly.
//!
//! # Algorithm
//!
//! 1. **Links.** Hop 0 (and only hop 0) uses the problem's `uplink` —
//!    the dispatcher's physical medium is not a choice. Every later hop
//!    picks the candidate `interconnect` link with the smallest modeled
//!    transfer time for that hop's boundary bytes (first candidate wins
//!    ties).
//! 2. **Devices.** Stages claim *contiguous blocks* of the pool sorted
//!    fastest-first (name ascending on ties). Only the slowest device
//!    of a block gates its stage (round-robin dealing), so an optimal
//!    matching always exists among contiguous partitions of the
//!    fastest `sum(replicas)` devices; a subset DP picks the exact
//!    block order minimizing the pipeline gate (`O(2^s * s)`, stages
//!    `s <= 16`). Homogeneous pools — and wider problems — keep the
//!    legacy fastest-to-heaviest rank order (heaviest stage by FLOPs
//!    descending, index ascending, gets the fastest block), which the
//!    DP reproduces on ties.
//! 3. **Replication.** Starting from one replica per stage, repeatedly
//!    add a replica to the current bottleneck stage while the worker
//!    budget allows, the stage's own service time strictly shrinks, and
//!    the overall gate does not worsen (equal co-bottlenecks hold the
//!    gate steady for a move and are balanced by later iterations); a
//!    final trim pass returns replicas that bought no throughput. An
//!    uplink-bound pipeline stops immediately — no amount of worker
//!    replication shrinks a shared dispatcher link.
//!
//! Greedily replicating the bottleneck is exact for homogeneous pools
//! (only lowering the max stage occupancy can raise throughput); with
//! heterogeneous devices the block DP makes each *assignment* exact for
//! the chosen replica vector, re-evaluated from scratch after every
//! move so a replica that would drag its stage's `f_min` down (and
//! therefore not pay for itself) is rejected.
//!
//! Everything here is pure and deterministic — no RNG, no clocks, no
//! artifact reads — so planner output is byte-stable across runs and
//! goldens-testable from synthetic stage costs alone.

use std::path::Path;
use std::time::Duration;

use crate::config::DeferConfig;
use crate::error::{DeferError, Result};
use crate::model::PartitionPlan;
use crate::netem::LinkSpec;
use crate::serial::json;
use crate::topology::Topology;

/// One edge device class available to host a worker replica.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Device label, echoed in the plan's per-stage assignment.
    pub name: String,
    /// Sustained compute budget in MFLOP/s.
    pub mflops: f64,
}

impl DeviceProfile {
    fn flops_per_sec(&self) -> f64 {
        self.mflops * 1e6
    }
}

/// Parse a device-profile JSON document:
/// `{"devices": [{"name": "jetson", "mflops": 200}, ...]}`.
pub fn parse_device_profiles(text: &str) -> Result<Vec<DeviceProfile>> {
    let v = json::parse(text)?;
    let mut out = Vec::new();
    for d in v.get("devices")?.as_arr()? {
        let name = d.get("name")?.as_str()?.to_string();
        let mflops = d.get("mflops")?.as_f64()?;
        if !(mflops > 0.0) {
            return Err(DeferError::Config(format!(
                "device {name:?}: mflops must be > 0, got {mflops}"
            )));
        }
        out.push(DeviceProfile { name, mflops });
    }
    if out.is_empty() {
        return Err(DeferError::Config(
            "device profile lists no devices".into(),
        ));
    }
    Ok(out)
}

/// Load a device-profile JSON file (see [`parse_device_profiles`]).
pub fn load_device_profiles(path: &Path) -> Result<Vec<DeviceProfile>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| DeferError::Config(format!("device profile {}: {e}", path.display())))?;
    parse_device_profiles(&text)
}

/// Modeled codec rates for the data socket, in seconds per raw
/// (uncompressed) activation byte, plus whether the runtime pipelines
/// codec and compute. The `Default` is [`CodecCost::ZERO`] — no codec
/// charge, the pre-calibration model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CodecCost {
    pub enc_secs_per_byte: f64,
    pub dec_secs_per_byte: f64,
    /// Runtime software-pipelines decode | compute | encode
    /// (`codec_pipeline`): the stage gates on the slowest phase instead
    /// of their sum, and compute overlaps egress.
    pub pipelined: bool,
}

impl CodecCost {
    /// No codec charge, inline aggregation — the pre-calibration model.
    pub const ZERO: CodecCost = CodecCost {
        enc_secs_per_byte: 0.0,
        dec_secs_per_byte: 0.0,
        pipelined: false,
    };

    /// A symmetric rate in GB/s of raw activation bytes; `gbps <= 0`
    /// charges nothing (but keeps the `pipelined` aggregation).
    pub fn from_gbps(gbps: f64, pipelined: bool) -> CodecCost {
        let s = if gbps > 0.0 { 1.0 / (gbps * 1e9) } else { 0.0 };
        CodecCost {
            enc_secs_per_byte: s,
            dec_secs_per_byte: s,
            pipelined,
        }
    }

    /// Built-in calibration table: single-thread secs/byte for this
    /// crate's codec implementations, measured offline on a laptop-class
    /// x86 core (order-of-magnitude; deterministic so plans stay
    /// byte-stable across runs and machines). Rates are over *raw* f32
    /// bytes; the LZ4 term is scaled by each serialization's inflation
    /// factor because LZ4 runs over the serialized bytes.
    pub fn calibrated(codec: &crate::serial::Codec, pipelined: bool) -> CodecCost {
        use crate::compress::Compression;
        use crate::serial::Serialization;
        // (encode ns/raw-byte, decode ns/raw-byte, serialized inflation)
        let (ser_enc, ser_dec, inflation) = match codec.serialization {
            Serialization::Json => (12.0, 9.0, 3.0),
            Serialization::Zfp(rate) => (2.5, 2.0, rate.0 as f64 / 32.0),
            Serialization::Binary => (0.15, 0.15, 1.0),
        };
        let (lz_enc, lz_dec) = match codec.compression {
            Compression::None => (0.0, 0.0),
            Compression::Lz4 => (2.5 * inflation, 0.8 * inflation),
        };
        CodecCost {
            enc_secs_per_byte: (ser_enc + lz_enc) * 1e-9,
            dec_secs_per_byte: (ser_dec + lz_dec) * 1e-9,
            pipelined,
        }
    }

    /// Live micro-measurement: encode/decode a synthetic 256 Ki-value
    /// payload a few times and keep the fastest pass. Sharper than the
    /// table on the actual host, but plans stop being byte-stable across
    /// machines — opt-in via `--codec-measure`.
    pub fn measure(codec: &crate::serial::Codec, pipelined: bool) -> CodecCost {
        let n = 256 * 1024;
        let data = crate::util::prng::Rng::new(7).normal_vec(n);
        let raw_bytes = (n * 4) as f64;
        let mut best_enc = f64::INFINITY;
        let mut best_dec = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let (wire, mid) = codec.encode_f32s(&data, None);
            best_enc = best_enc.min(t0.elapsed().as_secs_f64());
            let t1 = std::time::Instant::now();
            let _ = codec.decode_f32s(&wire, mid, n, None);
            best_dec = best_dec.min(t1.elapsed().as_secs_f64());
        }
        CodecCost {
            enc_secs_per_byte: best_enc / raw_bytes,
            dec_secs_per_byte: best_dec / raw_bytes,
            pipelined,
        }
    }

    /// Scale rates by a parallel-codec speedup (chunk-parallel path with
    /// `threads` pool workers); `threads == 0` is the serial path.
    pub fn over_threads(mut self, threads: usize) -> CodecCost {
        if threads > 1 {
            self.enc_secs_per_byte /= threads as f64;
            self.dec_secs_per_byte /= threads as f64;
        }
        self
    }

    fn charges_nothing(&self) -> bool {
        self.enc_secs_per_byte == 0.0 && self.dec_secs_per_byte == 0.0
    }
}

/// The [`CodecCost`] a [`DeferConfig`] describes: `--codec-gbps`
/// override first, then a `--codec-measure` live calibration, then the
/// built-in table — scaled by the chunk-parallel worker count (an
/// optimistic upper bound: the pool is shared by all replicas).
pub fn codec_cost_from_config(cfg: &DeferConfig) -> CodecCost {
    let base = match cfg.codec_gbps {
        Some(g) => CodecCost::from_gbps(g, cfg.codec_pipeline),
        None if cfg.codec_measure => CodecCost::measure(&cfg.codecs.data, cfg.codec_pipeline),
        None => CodecCost::calibrated(&cfg.codecs.data, cfg.codec_pipeline),
    };
    base.over_threads(cfg.codec_threads)
}

/// Micro-batching terms for the planner: a fixed per-message overhead
/// every endpoint pays per frame at `B = 1` (framing, syscalls, codec
/// setup, per-message bookkeeping), which coalescing `B` frames into
/// one wire message amortizes to `fixed_secs / B` — at the price of up
/// to `B - 1` extra gate periods of queueing latency for the first
/// frame of a batch. The `Default` is [`BatchCost::ZERO`] — batching is
/// not priced and the planner keeps `B = 1`, so pre-batching plans stay
/// byte-identical.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchCost {
    /// Per-frame fixed overhead at `B = 1`, in seconds.
    pub fixed_secs: f64,
    /// Largest batch size the runtime may use (>= 1).
    pub max_batch: usize,
    /// Latency budget in seconds: a batch size `B` is only feasible
    /// when the extra wait it can add — `(B - 1)` gate periods — fits.
    /// `<= 0` = unbounded.
    pub latency_budget_secs: f64,
}

impl BatchCost {
    /// No batch pricing: the planner keeps `B = 1`.
    pub const ZERO: BatchCost = BatchCost {
        fixed_secs: 0.0,
        max_batch: 1,
        latency_budget_secs: 0.0,
    };

    fn charges_nothing(&self) -> bool {
        !(self.fixed_secs > 0.0) || self.max_batch <= 1
    }

    /// The amortized per-frame charge at batch size `b`.
    fn per_frame(&self, b: usize) -> f64 {
        if self.charges_nothing() {
            0.0
        } else {
            self.fixed_secs / b.max(1) as f64
        }
    }
}

impl Default for BatchCost {
    fn default() -> Self {
        BatchCost::ZERO
    }
}

/// The [`BatchCost`] a [`DeferConfig`] describes: `--batch-overhead-us`
/// per frame at `B = 1`, amortizable up to `--batch`, bounded by
/// `--batch-latency-ms`.
pub fn batch_cost_from_config(cfg: &DeferConfig) -> BatchCost {
    BatchCost {
        fixed_secs: cfg.batch_overhead_us * 1e-6,
        max_batch: cfg.batch.max(1),
        latency_budget_secs: cfg.batch_latency_ms * 1e-3,
    }
}

/// What the planner needs to know about one pipeline stage — exactly the
/// fields a `PartitionSpec` already carries.
#[derive(Clone, Debug)]
pub struct StageCost {
    /// FLOPs to execute the stage once.
    pub flops: u64,
    /// Uncompressed activation bytes entering the stage.
    pub input_bytes: u64,
    /// Uncompressed activation bytes leaving the stage.
    pub output_bytes: u64,
}

/// A complete placement problem: stage costs, the device pool, the
/// worker budget, and the link vocabulary.
#[derive(Clone, Debug)]
pub struct PlacementProblem {
    pub stages: Vec<StageCost>,
    /// Devices available to host worker replicas.
    pub devices: Vec<DeviceProfile>,
    /// Max worker replicas to place in total (>= number of stages,
    /// <= number of devices).
    pub worker_budget: usize,
    /// The dispatcher's physical medium — always hop 0.
    pub uplink: LinkSpec,
    /// Candidate links for every later hop (inter-stage and return).
    /// Empty = the uplink is the only medium.
    pub interconnect: Vec<LinkSpec>,
    /// Codec service rates charged per frame ([`CodecCost::ZERO`] = the
    /// pre-calibration model).
    pub codec: CodecCost,
    /// Price the legacy junction-relay data plane: interior-boundary
    /// egress doubles when either side of the boundary is replicated
    /// (the frame detours through the coordinator host). `false` = the
    /// worker-owned data plane, direct replica-to-replica egress.
    pub relay_junctions: bool,
    /// Micro-batching terms ([`BatchCost::ZERO`] = batching not priced,
    /// the planner keeps `B = 1`).
    pub batch: BatchCost,
}

impl PlacementProblem {
    /// Build the problem a [`DeferConfig`] + partition plan describe:
    /// stage costs from the plan's FLOPs and boundary shapes; the device
    /// pool from `device_profile` (or a homogeneous pool of
    /// `emulated_mflops`-speed devices when no profile is given); hop 0
    /// of `per_hop_links` as the uplink and the remaining distinct
    /// entries as interconnect candidates.
    pub fn from_config(cfg: &DeferConfig, plan: &PartitionPlan) -> Result<PlacementProblem> {
        let stages: Vec<StageCost> = plan
            .parts
            .iter()
            .map(|p| StageCost {
                flops: p.flops,
                input_bytes: p.input_bytes(),
                output_bytes: p.output_bytes(),
            })
            .collect();
        let (uplink, interconnect) = links_from_config(cfg);
        let (devices, worker_budget) = device_pool_from_config(cfg)?;
        Ok(PlacementProblem {
            stages,
            devices,
            worker_budget,
            uplink,
            interconnect,
            codec: codec_cost_from_config(cfg),
            relay_junctions: cfg.relay_junctions,
            batch: batch_cost_from_config(cfg),
        })
    }
}

/// The link vocabulary a [`DeferConfig`] describes for planning: hop 0 of
/// `per_hop_links` is the dispatcher uplink (the physical medium, not a
/// choice) and the remaining *distinct* entries are the interconnect
/// candidates for interior hops. An empty `per_hop_links` makes the
/// uniform `link` both. Shared by [`PlacementProblem::from_config`] and
/// the repartition planner (`crate::repartition`), which cannot take
/// per-hop lists literally — with `auto_partition` the number of hops is
/// itself a planning output.
pub fn links_from_config(cfg: &DeferConfig) -> (LinkSpec, Vec<LinkSpec>) {
    let uplink = cfg.per_hop_links.first().copied().unwrap_or(cfg.link);
    let tail: &[LinkSpec] = match cfg.per_hop_links.len() {
        0 => std::slice::from_ref(&cfg.link),
        1 => &cfg.per_hop_links[..],
        _ => &cfg.per_hop_links[1..],
    };
    let mut interconnect: Vec<LinkSpec> = Vec::new();
    for l in tail {
        if !interconnect.contains(l) {
            interconnect.push(*l);
        }
    }
    (uplink, interconnect)
}

/// The worker pool + budget a [`DeferConfig`] describes: the JSON device
/// profile when given, else a homogeneous pool of `emulated_mflops`-speed
/// devices sized by `workers_budget` (default `nodes`).
pub fn device_pool_from_config(cfg: &DeferConfig) -> Result<(Vec<DeviceProfile>, usize)> {
    match &cfg.device_profile {
        Some(path) => {
            let devices = load_device_profiles(path)?;
            let budget = if cfg.workers_budget > 0 {
                cfg.workers_budget
            } else {
                devices.len()
            };
            if budget > devices.len() {
                return Err(DeferError::Config(format!(
                    "workers budget {budget} exceeds the {} profiled devices",
                    devices.len()
                )));
            }
            Ok((devices, budget))
        }
        None => {
            if !(cfg.emulated_mflops > 0.0) {
                return Err(DeferError::Config(
                    "planning needs a device model: pass --device-profile FILE \
                     or --emulated-mflops RATE so stage compute times are defined"
                        .into(),
                ));
            }
            let budget = if cfg.workers_budget > 0 {
                cfg.workers_budget
            } else {
                cfg.nodes
            };
            let devices = (0..budget)
                .map(|i| DeviceProfile {
                    name: format!("edge{i}"),
                    mflops: cfg.emulated_mflops,
                })
                .collect();
            Ok((devices, budget))
        }
    }
}

/// What gates the planned pipeline's throughput.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bottleneck {
    /// The shared dispatcher uplink (hop 0) — replication cannot help.
    Uplink,
    /// Stage `i`'s per-replica service time.
    Stage(usize),
}

/// One stage's slot in the plan, with the modeled times behind it.
#[derive(Clone, Debug)]
pub struct StagePlacement {
    pub replicas: usize,
    /// Names of the devices hosting this stage's replicas.
    pub devices: Vec<String>,
    /// Per-replica compute time per frame (gated by the slowest device).
    pub compute: Duration,
    /// Per-replica codec time per frame (decode input + encode output);
    /// zero under the pre-calibration model.
    pub codec: Duration,
    /// Per-replica shaped egress write per frame. Under the relay model
    /// this includes the junction detour (see `relayed`).
    pub egress: Duration,
    /// The egress was doubled by the legacy relay model (replicated
    /// interior boundary under `relay_junctions`).
    pub relayed: bool,
    /// Amortized per-frame batch overhead (`fixed / B`); zero when
    /// batching is not priced.
    pub batch: Duration,
    /// Effective stage occupancy per frame: the per-replica busy time
    /// (inline: `codec + compute + egress`; pipelined:
    /// `max(decode, compute, encode + egress)`; plus the amortized
    /// batch overhead) divided by `R`.
    pub service: Duration,
}

/// The planner's output: replica counts, hop links, and the predicted
/// steady-state throughput they buy. `topology()` turns it into the
/// same [`Topology`] a hand-written config would produce.
#[derive(Clone, Debug)]
pub struct PlacementPlan {
    pub stages: Vec<StagePlacement>,
    /// Per-hop links, `stages + 1` entries (hop 0 = uplink).
    pub hop_links: Vec<LinkSpec>,
    /// Modeled occupancy of the shared dispatcher uplink per frame.
    pub uplink_time: Duration,
    pub bottleneck: Bottleneck,
    /// Modeled steady-state frames/second.
    pub predicted_throughput: f64,
    /// Planned batch size (1 = unbatched; > 1 only when the problem
    /// prices a per-frame overhead that amortization beats).
    pub batch: usize,
    /// The priced per-frame fixed overhead at `B = 1` (zero when
    /// batching is not priced).
    pub batch_overhead: Duration,
}

impl PlacementPlan {
    /// Total worker replicas the plan places.
    pub fn num_workers(&self) -> usize {
        self.stages.iter().map(|s| s.replicas).sum()
    }

    /// Replica counts in stage order.
    pub fn replica_counts(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.replicas).collect()
    }

    /// The [`Topology`] this plan describes — consumed by the chain
    /// runner exactly like a hand-written one.
    pub fn topology(&self) -> Result<Topology> {
        Topology::new(&self.replica_counts(), self.hop_links.clone())
    }

    /// Stable human-readable rendering (also the goldens surface: the
    /// planner is deterministic, so this string is byte-identical across
    /// runs on the same problem).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "placement plan: {} stage(s), {} worker(s), predicted {:.3} cycles/s\n",
            self.stages.len(),
            self.num_workers(),
            self.predicted_throughput
        ));
        out.push_str(&format!(
            "  hop 0 uplink {} ({:.3} ms/frame{})\n",
            self.hop_links[0].label(),
            self.uplink_time.as_secs_f64() * 1e3,
            if self.bottleneck == Bottleneck::Uplink {
                ", bottleneck"
            } else {
                ""
            }
        ));
        // The batch line appears only when batching is priced, keeping
        // pre-batching renders byte-identical.
        if self.batch_overhead > Duration::ZERO {
            out.push_str(&format!(
                "  batch: B={} per-frame overhead {:.3} ms amortized to {:.3} ms\n",
                self.batch,
                self.batch_overhead.as_secs_f64() * 1e3,
                self.batch_overhead.as_secs_f64() * 1e3 / self.batch as f64
            ));
        }
        for (i, st) in self.stages.iter().enumerate() {
            // The codec segment appears only when it is charged, keeping
            // pre-calibration renders byte-identical.
            let codec = if st.codec > Duration::ZERO {
                format!(" + codec {:.3} ms", st.codec.as_secs_f64() * 1e3)
            } else {
                String::new()
            };
            // The relay marker appears only under the legacy relay cost
            // model, keeping worker-owned renders byte-identical to the
            // historical goldens.
            let relay = if st.relayed { " (+relay)" } else { "" };
            // The batch segment appears only when batching is priced.
            let batch = if st.batch > Duration::ZERO {
                format!(" + batch {:.3} ms", st.batch.as_secs_f64() * 1e3)
            } else {
                String::new()
            };
            out.push_str(&format!(
                "  stage {i}: x{} on [{}] via {}{relay}, compute {:.3} ms{codec} + \
                 egress {:.3} ms{batch} -> service {:.3} ms/frame{}\n",
                st.replicas,
                st.devices.join(", "),
                self.hop_links[i + 1].label(),
                st.compute.as_secs_f64() * 1e3,
                st.egress.as_secs_f64() * 1e3,
                st.service.as_secs_f64() * 1e3,
                if self.bottleneck == Bottleneck::Stage(i) {
                    ", bottleneck"
                } else {
                    ""
                }
            ));
        }
        out
    }
}

/// Modeled occupancy of one shaped link for `bytes`: serialization at
/// the link rate plus expected propagation (latency + jitter/2). Shared
/// with the repartition planner so both passes price bytes identically.
pub(crate) fn transfer_secs(link: &LinkSpec, bytes: u64) -> f64 {
    let mut t = link.latency.as_secs_f64() + link.jitter.as_secs_f64() / 2.0;
    if let Some(bps) = link.bandwidth_bps {
        t += bytes as f64 * 8.0 / bps as f64;
    }
    t
}

/// The interconnect candidate with the least modeled transfer time for
/// `bytes` (first candidate wins ties) — the interior-hop link rule,
/// shared with the repartition planner.
pub(crate) fn best_link_for(candidates: &[LinkSpec], bytes: u64) -> LinkSpec {
    *candidates
        .iter()
        .min_by(|a, b| {
            transfer_secs(a, bytes)
                .partial_cmp(&transfer_secs(b, bytes))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("non-empty candidates")
}

struct Eval {
    stages: Vec<StagePlacement>,
    /// Seconds per frame at the pipeline gate (1 / throughput).
    gate: f64,
    bottleneck: Bottleneck,
}

/// Model one replica vector at batch size `batch`: assign devices,
/// compute per-stage service times, find the gate. Pure function of its
/// inputs.
fn evaluate(p: &PlacementProblem, hop_links: &[LinkSpec], replicas: &[usize], batch: usize) -> Eval {
    let s = p.stages.len();
    // Per-frame fixed overhead, amortized over the frames sharing one
    // wire message. Charged after the pipelined max — per-message work
    // does not overlap the phases it frames.
    let batch_charge = p.batch.per_frame(batch);

    // Per-stage cost terms that do not depend on the device assignment.
    let mut dec = vec![0.0f64; s];
    let mut enc = vec![0.0f64; s];
    let mut egress = vec![0.0f64; s];
    let mut relayed_flags = vec![false; s];
    for i in 0..s {
        // Legacy relay model: a replicated *interior* boundary detours
        // through the coordinator host, so the frame crosses the hop
        // twice (sender -> relay, relay -> receiver). The uplink and
        // return hops never double — the relay is co-located with the
        // dispatcher. Worker-owned wiring (the default) is one direct
        // crossing.
        let relayed = p.relay_junctions && i + 1 < s && (replicas[i] > 1 || replicas[i + 1] > 1);
        let hop_crossings = if relayed { 2.0 } else { 1.0 };
        relayed_flags[i] = relayed;
        egress[i] = hop_crossings * transfer_secs(&hop_links[i + 1], p.stages[i].output_bytes);
        // Codec charges (zero under the pre-calibration model): a
        // replica decodes its input and encodes its output every frame.
        dec[i] = p.codec.dec_secs_per_byte * p.stages[i].input_bytes as f64;
        enc[i] = p.codec.enc_secs_per_byte * p.stages[i].output_bytes as f64;
    }
    // A stage's service time as a function of its slowest device — the
    // one quantity the device assignment controls (round-robin dealing
    // gates every replica on the block's f_min).
    let service_of = |i: usize, f_min: f64| -> f64 {
        let compute = p.stages[i].flops as f64 / f_min;
        let busy = if p.codec.pipelined && !p.codec.charges_nothing() {
            // Software-pipelined phases overlap; the slowest gates.
            dec[i].max(compute).max(enc[i] + egress[i])
        } else {
            dec[i] + compute + enc[i] + egress[i]
        } + batch_charge;
        busy / replicas[i] as f64
    };

    // Deterministic ranks: stages by FLOPs (descending, index ascending)
    // and the pool fastest-first (name ascending on ties).
    let mut stage_order: Vec<usize> = (0..s).collect();
    stage_order.sort_by(|&a, &b| p.stages[b].flops.cmp(&p.stages[a].flops).then(a.cmp(&b)));
    let mut pool: Vec<&DeviceProfile> = p.devices.iter().collect();
    pool.sort_by(|a, b| {
        b.mflops
            .partial_cmp(&a.mflops)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.name.cmp(&b.name))
    });
    let assigned = assign_blocks(&stage_order, &pool, replicas, &service_of);

    let uplink_secs = uplink_occupancy(p, &hop_links[0]) + batch_charge;
    let mut gate = uplink_secs;
    let mut bottleneck = Bottleneck::Uplink;
    let mut stages = Vec::with_capacity(s);
    for i in 0..s {
        let f_min = assigned[i]
            .iter()
            .map(|d| d.flops_per_sec())
            .fold(f64::INFINITY, f64::min);
        let compute = p.stages[i].flops as f64 / f_min;
        let service = service_of(i, f_min);
        if service > gate {
            gate = service;
            bottleneck = Bottleneck::Stage(i);
        }
        stages.push(StagePlacement {
            replicas: replicas[i],
            devices: assigned[i].iter().map(|d| d.name.clone()).collect(),
            compute: Duration::from_secs_f64(compute),
            codec: Duration::from_secs_f64(dec[i] + enc[i]),
            egress: Duration::from_secs_f64(egress[i]),
            relayed: relayed_flags[i],
            batch: Duration::from_secs_f64(batch_charge),
            service: Duration::from_secs_f64(service),
        });
    }
    Eval {
        stages,
        gate,
        bottleneck,
    }
}

/// Stage count past which the subset DP is skipped (`2^s` states).
const MAX_DP_STAGES: usize = 16;

/// Partition the speed-sorted pool into one contiguous block of
/// `replicas[i]` devices per stage. Only the slowest device of a block
/// gates its stage, so an optimal device matching always exists among
/// the contiguous partitions of the fastest `sum(replicas)` devices
/// (swapping any device for a faster unused one never raises a block's
/// f_min, and uncrossing two interleaved blocks never lowers either
/// f_min). A DP over stage subsets then picks the exact block order
/// minimizing the pipeline gate in `O(2^s * s)`. Homogeneous pools,
/// single stages and problems past [`MAX_DP_STAGES`] keep the legacy
/// fastest-to-heaviest rank order, which the DP reproduces on ties.
fn assign_blocks<'a>(
    stage_order: &[usize],
    pool: &[&'a DeviceProfile],
    replicas: &[usize],
    service_of: &dyn Fn(usize, f64) -> f64,
) -> Vec<Vec<&'a DeviceProfile>> {
    let s = replicas.len();
    let total: usize = replicas.iter().sum();
    let greedy = || {
        // Heaviest stage claims the fastest devices (deterministic ranks).
        let mut assigned: Vec<Vec<&DeviceProfile>> = vec![Vec::new(); s];
        let mut cursor = 0usize;
        for &i in stage_order {
            assigned[i] = pool[cursor..cursor + replicas[i]].to_vec();
            cursor += replicas[i];
        }
        assigned
    };
    let homogeneous = pool[..total].windows(2).all(|w| w[0].mflops == w[1].mflops);
    if s <= 1 || s > MAX_DP_STAGES || homogeneous {
        return greedy();
    }

    // dp[mask] = smallest achievable max service over the stages in
    // `mask`, laid out (in some order) over the first `cnt[mask]` pool
    // slots. The prefix length is mask-determined — block sizes are
    // fixed per stage — so the state is just the subset.
    let full = (1usize << s) - 1;
    let mut cnt = vec![0usize; full + 1];
    for mask in 1..=full {
        let lsb = mask.trailing_zeros() as usize;
        cnt[mask] = cnt[mask & (mask - 1)] + replicas[lsb];
    }
    let mut dp = vec![f64::INFINITY; full + 1];
    dp[0] = 0.0;
    for mask in 0..full {
        if !dp[mask].is_finite() {
            continue;
        }
        for i in 0..s {
            if mask & (1 << i) != 0 {
                continue;
            }
            let f_min = pool[cnt[mask] + replicas[i] - 1].flops_per_sec();
            let cost = dp[mask].max(service_of(i, f_min));
            let next = mask | (1 << i);
            if cost < dp[next] {
                dp[next] = cost;
            }
        }
    }

    // Walk the optimum back to an assignment, slowest block first.
    // Among optimum-achieving choices take the stage the greedy order
    // ranks last, so ties reproduce the legacy fastest-to-heaviest
    // layout and plans stay byte-stable.
    const EPS: f64 = 1e-12;
    let mut rank = vec![0usize; s];
    for (r, &i) in stage_order.iter().enumerate() {
        rank[i] = r;
    }
    let mut assigned: Vec<Vec<&DeviceProfile>> = vec![Vec::new(); s];
    let mut mask = full;
    while mask != 0 {
        let mut pick: Option<usize> = None;
        for i in 0..s {
            if mask & (1 << i) == 0 {
                continue;
            }
            let prev = mask & !(1 << i);
            let f_min = pool[cnt[prev] + replicas[i] - 1].flops_per_sec();
            if dp[prev].max(service_of(i, f_min)) <= dp[mask] + EPS {
                pick = match pick {
                    Some(j) if rank[j] >= rank[i] => Some(j),
                    _ => Some(i),
                };
            }
        }
        let i = pick.expect("an optimal DP path always exists");
        let prev = mask & !(1 << i);
        assigned[i] = pool[cnt[prev]..cnt[prev] + replicas[i]].to_vec();
        mask = prev;
    }
    assigned
}

/// Modeled occupancy of the shared dispatcher uplink: the shaped
/// transfer of stage 0's input, plus the dispatcher's own encode of it
/// (overlapped when the runtime pipelines encode|send).
fn uplink_occupancy(p: &PlacementProblem, uplink: &LinkSpec) -> f64 {
    let transfer = transfer_secs(uplink, p.stages[0].input_bytes);
    let enc = p.codec.enc_secs_per_byte * p.stages[0].input_bytes as f64;
    if p.codec.pipelined {
        transfer.max(enc)
    } else {
        transfer + enc
    }
}

/// Plan the throughput-maximizing topology for `p` (see module docs for
/// the cost model and algorithm). Deterministic: same problem, same
/// plan, byte-identical rendering.
pub fn plan(p: &PlacementProblem) -> Result<PlacementPlan> {
    let s = p.stages.len();
    if s == 0 {
        return Err(DeferError::Config("placement needs at least one stage".into()));
    }
    if p.worker_budget < s {
        return Err(DeferError::Config(format!(
            "workers budget {} cannot cover {s} stages (one replica each)",
            p.worker_budget
        )));
    }
    if p.devices.len() < p.worker_budget {
        return Err(DeferError::Config(format!(
            "workers budget {} exceeds the {} available devices",
            p.worker_budget,
            p.devices.len()
        )));
    }
    if let Some(d) = p.devices.iter().find(|d| !(d.mflops > 0.0)) {
        return Err(DeferError::Config(format!(
            "device {:?}: mflops must be > 0, got {}",
            d.name, d.mflops
        )));
    }

    // Hop links: the uplink is physical; later hops pick the candidate
    // with the least modeled transfer time for their boundary bytes
    // (min_by keeps the first candidate on ties).
    let candidates: &[LinkSpec] = if p.interconnect.is_empty() {
        std::slice::from_ref(&p.uplink)
    } else {
        &p.interconnect
    };
    let mut hop_links = Vec::with_capacity(s + 1);
    hop_links.push(p.uplink);
    for h in 1..=s {
        hop_links.push(best_link_for(candidates, p.stages[h - 1].output_bytes));
    }

    // Greedy replication: grow the bottleneck stage while the budget
    // allows. A move is accepted when the bottleneck stage's own service
    // time strictly shrinks without worsening the overall gate — the
    // gate itself may hold steady when an equally-slow co-bottleneck
    // remains, which a later iteration then replicates (this is how two
    // equal stages end up balanced instead of the loop stalling). A
    // replica that makes its stage *worse* (a slow device dragging the
    // round-robin f_min down) or shifts a fast device away from a stage
    // that needed it more is rejected, ending the search.
    const EPS: f64 = 1e-12;
    let solve_at = |batch: usize| -> Eval {
        let mut replicas = vec![1usize; s];
        let mut eval = evaluate(p, &hop_links, &replicas, batch);
        while replicas.iter().sum::<usize>() < p.worker_budget {
            let b = match eval.bottleneck {
                Bottleneck::Stage(i) => i,
                Bottleneck::Uplink => break,
            };
            let mut cand = replicas.clone();
            cand[b] += 1;
            let cand_eval = evaluate(p, &hop_links, &cand, batch);
            let shrinks = cand_eval.stages[b].service.as_secs_f64() + EPS
                < eval.stages[b].service.as_secs_f64();
            if shrinks && cand_eval.gate <= eval.gate + EPS {
                replicas = cand;
                eval = cand_eval;
            } else {
                break;
            }
        }

        // Trim replicas that buy nothing: the budget is permission, not
        // an obligation, and the loop above can overshoot when it runs
        // out mid-balancing (e.g. two equal stages and one spare
        // worker).
        for i in 0..s {
            while replicas[i] > 1 {
                let mut cand = replicas.clone();
                cand[i] -= 1;
                let cand_eval = evaluate(p, &hop_links, &cand, batch);
                if cand_eval.gate <= eval.gate + EPS {
                    replicas = cand;
                    eval = cand_eval;
                } else {
                    break;
                }
            }
        }
        eval
    };

    // Micro-batch pricing: coalescing B frames into one message
    // amortizes the fixed per-frame overhead to `fixed / B`, at a
    // worst-case queueing cost of `(B - 1)` gate periods. The gate is
    // non-increasing in B and the per-step improvement only shrinks, so
    // search B upward, keep the smallest B achieving the best feasible
    // gate, and stop as soon as the gate stops improving or the latency
    // budget is exceeded.
    let max_b = if p.batch.charges_nothing() {
        1
    } else {
        p.batch.max_batch.max(1)
    };
    let mut best_b = 1usize;
    let mut best_eval = solve_at(1);
    for b in 2..=max_b {
        let eval = solve_at(b);
        let feasible = p.batch.latency_budget_secs <= 0.0
            || (b - 1) as f64 * eval.gate <= p.batch.latency_budget_secs + EPS;
        if !feasible || eval.gate + EPS >= best_eval.gate {
            break;
        }
        best_b = b;
        best_eval = eval;
    }
    let eval = best_eval;

    Ok(PlacementPlan {
        stages: eval.stages,
        hop_links,
        uplink_time: Duration::from_secs_f64(
            uplink_occupancy(p, &p.uplink) + p.batch.per_frame(best_b),
        ),
        bottleneck: eval.bottleneck,
        predicted_throughput: 1.0 / eval.gate,
        batch: best_b,
        batch_overhead: Duration::from_secs_f64(if p.batch.charges_nothing() {
            0.0
        } else {
            p.batch.fixed_secs
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn homogeneous(n: usize, mflops: f64) -> Vec<DeviceProfile> {
        (0..n)
            .map(|i| DeviceProfile {
                name: format!("edge{i}"),
                mflops,
            })
            .collect()
    }

    #[test]
    fn device_profile_json_round_trip() {
        let devs = parse_device_profiles(
            r#"{"devices": [{"name": "jetson", "mflops": 200},
                            {"name": "pi", "mflops": 50}]}"#,
        )
        .unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].name, "jetson");
        assert_eq!(devs[1].mflops, 50.0);
        assert!(parse_device_profiles(r#"{"devices": []}"#).is_err());
        assert!(parse_device_profiles(
            r#"{"devices": [{"name": "x", "mflops": 0}]}"#
        )
        .is_err());
    }

    #[test]
    fn uplink_bound_pipeline_keeps_one_replica_each() {
        // Cheap compute, huge input over a slow uplink: the shared
        // dispatcher link gates the pipeline, so the planner must not
        // spend budget on replicas that cannot help.
        let p = PlacementProblem {
            stages: vec![
                StageCost {
                    flops: 1_000,
                    input_bytes: 50_000_000,
                    output_bytes: 1_000,
                },
                StageCost {
                    flops: 1_000,
                    input_bytes: 1_000,
                    output_bytes: 1_000,
                },
            ],
            devices: homogeneous(6, 1000.0),
            worker_budget: 6,
            uplink: LinkSpec::wifi(),
            interconnect: vec![LinkSpec::gigabit_lan()],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        };
        let plan = plan(&p).unwrap();
        assert_eq!(plan.replica_counts(), vec![1, 1]);
        assert_eq!(plan.bottleneck, Bottleneck::Uplink);
    }

    #[test]
    fn slow_replica_that_would_gate_the_stage_is_rejected() {
        // One stage, budget 2, devices 200 + 50 MFLOP/s. Round-robin
        // dealing gates on the slowest replica: 2 replicas at f_min=50
        // serve a frame every flops/(2*50e6) s, worse than one fast
        // replica at flops/200e6 s — the planner must keep R=1.
        let p = PlacementProblem {
            stages: vec![StageCost {
                flops: 200_000_000,
                input_bytes: 1_000,
                output_bytes: 1_000,
            }],
            devices: vec![
                DeviceProfile {
                    name: "fast".into(),
                    mflops: 200.0,
                },
                DeviceProfile {
                    name: "slow".into(),
                    mflops: 50.0,
                },
            ],
            worker_budget: 2,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        };
        let plan = plan(&p).unwrap();
        assert_eq!(plan.replica_counts(), vec![1]);
        assert_eq!(plan.stages[0].devices, vec!["fast".to_string()]);
    }

    #[test]
    fn dp_matching_beats_fastest_to_heaviest_greedy() {
        // Two stages (196 and 100 MFLOP), devices at 90/88/86 MFLOP/s,
        // budget 3. Replication settles on [2, 1]; the assignment then
        // decides the gate. Fastest-to-heaviest would give stage 0 (the
        // heaviest) {d90, d88} and stage 1 {d86}: gate = 100/86 =
        // 1.1628 s on stage 1. The exact DP instead hands stage 1 the
        // single fastest device and stage 0 the {d88, d86} block:
        // gate = max(196/(2*86), 100/90) = 1.1395 s on stage 0 — the
        // configuration greedy ranking can never reach.
        let p = PlacementProblem {
            stages: vec![
                StageCost {
                    flops: 196_000_000,
                    input_bytes: 1_000,
                    output_bytes: 1_000,
                },
                StageCost {
                    flops: 100_000_000,
                    input_bytes: 1_000,
                    output_bytes: 1_000,
                },
            ],
            devices: vec![
                DeviceProfile {
                    name: "d90".into(),
                    mflops: 90.0,
                },
                DeviceProfile {
                    name: "d88".into(),
                    mflops: 88.0,
                },
                DeviceProfile {
                    name: "d86".into(),
                    mflops: 86.0,
                },
            ],
            worker_budget: 3,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        };
        let plan = plan(&p).unwrap();
        assert_eq!(plan.replica_counts(), vec![2, 1]);
        assert_eq!(
            plan.stages[0].devices,
            vec!["d88".to_string(), "d86".to_string()]
        );
        assert_eq!(plan.stages[1].devices, vec!["d90".to_string()]);
        assert_eq!(plan.bottleneck, Bottleneck::Stage(0));
        let gate = 1.0 / plan.predicted_throughput;
        assert!((gate - 196.0 / (2.0 * 86.0)).abs() < 1e-9, "{gate}");
        // Strictly better than the greedy layout's 100/86 s gate.
        assert!(gate < 100.0 / 86.0 - 1e-9, "{gate}");
    }

    #[test]
    fn codec_charge_moves_the_bottleneck() {
        // Uplink-bound without codec time; a slow codec makes the stage
        // the gate and replication worthwhile — exactly the blind spot
        // ROADMAP item (c) called out.
        let mk = |codec: CodecCost| PlacementProblem {
            stages: vec![StageCost {
                flops: 50_000_000,
                input_bytes: 5_000_000,
                output_bytes: 5_000_000,
            }],
            devices: homogeneous(2, 10_000.0),
            worker_budget: 2,
            uplink: LinkSpec::gigabit_lan(),
            interconnect: vec![LinkSpec::gigabit_lan()],
            codec,
            relay_junctions: false,
            batch: BatchCost::ZERO,
        };
        let without = plan(&mk(CodecCost::ZERO)).unwrap();
        assert_eq!(without.bottleneck, Bottleneck::Uplink);
        // 0.05 GB/s codec: 100 ms decode + 100 ms encode per frame
        // dwarfs the 40 ms uplink; the stage gates even at R=2.
        let with = plan(&mk(CodecCost::from_gbps(0.05, false))).unwrap();
        assert_eq!(with.bottleneck, Bottleneck::Stage(0));
        assert_eq!(with.replica_counts(), vec![2]);
        assert!(with.predicted_throughput < without.predicted_throughput);
        assert!(with.stages[0].codec > Duration::ZERO);
        assert!(with.render().contains("codec"), "{}", with.render());
        assert!(!without.render().contains("codec"), "{}", without.render());
    }

    #[test]
    fn pipelined_codec_overlaps_phases() {
        let mk = |pipelined: bool| PlacementProblem {
            stages: vec![StageCost {
                flops: 100_000_000,
                input_bytes: 1_000_000,
                output_bytes: 1_000_000,
            }],
            devices: homogeneous(1, 1_000.0),
            worker_budget: 1,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::from_gbps(0.1, pipelined),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        };
        let inline = plan(&mk(false)).unwrap();
        let pipelined = plan(&mk(true)).unwrap();
        // Inline: 10 + 100 + 10 ms = 120 ms; pipelined: max = 100 ms.
        let s_in = inline.stages[0].service.as_secs_f64();
        let s_pl = pipelined.stages[0].service.as_secs_f64();
        assert!((s_in - 0.120).abs() < 1e-6, "{s_in}");
        assert!((s_pl - 0.100).abs() < 1e-6, "{s_pl}");
    }

    #[test]
    fn calibration_table_orders_codecs_sanely() {
        use crate::serial::Codec;
        let sweep = Codec::paper_sweep();
        let json_lz4 = CodecCost::calibrated(&sweep[0], true);
        let json_raw = CodecCost::calibrated(&sweep[1], true);
        let zfp_lz4 = CodecCost::calibrated(&sweep[2], true);
        // JSON is the slowest arm; LZ4 adds cost on top of each.
        assert!(json_raw.enc_secs_per_byte > zfp_lz4.enc_secs_per_byte);
        assert!(json_lz4.enc_secs_per_byte > json_raw.enc_secs_per_byte);
        // Parallel-codec scaling divides rates.
        let par = zfp_lz4.over_threads(4);
        assert!((par.enc_secs_per_byte - zfp_lz4.enc_secs_per_byte / 4.0).abs() < 1e-15);
        // gbps = 0 charges nothing.
        assert!(CodecCost::from_gbps(0.0, true).charges_nothing());
    }

    #[test]
    fn relay_model_charges_the_hidden_interior_hop() {
        // Two stages, big inter-stage boundary, stage 0 replicated:
        // under the legacy relay wiring the boundary detours through
        // the coordinator host, so its egress must double — and only
        // there (uplink and return hops host the relay locally).
        let mk = |relay: bool| PlacementProblem {
            stages: vec![
                StageCost {
                    flops: 200_000_000,
                    input_bytes: 1_000,
                    output_bytes: 5_000_000,
                },
                StageCost {
                    flops: 10_000_000,
                    input_bytes: 5_000_000,
                    output_bytes: 1_000,
                },
            ],
            devices: homogeneous(3, 100.0),
            worker_budget: 3,
            uplink: LinkSpec::gigabit_lan(),
            interconnect: vec![LinkSpec::gigabit_lan()],
            codec: CodecCost::default(),
            relay_junctions: relay,
            batch: BatchCost::ZERO,
        };
        let direct = plan(&mk(false)).unwrap();
        let relay = plan(&mk(true)).unwrap();
        assert_eq!(direct.replica_counts(), vec![2, 1]);
        assert!(!direct.stages[0].relayed);
        assert!(relay.stages[0].relayed, "replicated boundary not relayed");
        assert!(
            !relay.stages[1].relayed,
            "return hop must not charge a relay"
        );
        let e_direct = direct.stages[0].egress.as_secs_f64();
        let e_relay = relay.stages[0].egress.as_secs_f64();
        // Durations quantize to whole nanoseconds; allow that much slack.
        assert!((e_relay - 2.0 * e_direct).abs() < 1e-8, "{e_relay} vs {e_direct}");
        assert!(relay.predicted_throughput <= direct.predicted_throughput);
        assert!(relay.render().contains("(+relay)"), "{}", relay.render());
        assert!(!direct.render().contains("(+relay)"), "{}", direct.render());
    }

    #[test]
    fn batch_amortization_raises_throughput_and_respects_budget() {
        // One stage, 10 ms compute, 5 ms per-frame fixed overhead: at
        // B=1 the gate is 15 ms; amortized over B=8 it approaches
        // 10.625 ms. Unbounded budget picks the largest useful B.
        let mk = |batch: BatchCost| PlacementProblem {
            stages: vec![StageCost {
                flops: 10_000_000,
                input_bytes: 1_000,
                output_bytes: 1_000,
            }],
            devices: homogeneous(1, 1_000.0),
            worker_budget: 1,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch,
        };
        let unpriced = plan(&mk(BatchCost::ZERO)).unwrap();
        assert_eq!(unpriced.batch, 1);
        assert_eq!(unpriced.batch_overhead, Duration::ZERO);
        assert!(!unpriced.render().contains("batch"), "{}", unpriced.render());

        let priced = plan(&mk(BatchCost {
            fixed_secs: 5e-3,
            max_batch: 8,
            latency_budget_secs: 0.0,
        }))
        .unwrap();
        assert_eq!(priced.batch, 8);
        let gate = 1.0 / priced.predicted_throughput;
        assert!((gate - (0.010 + 0.005 / 8.0)).abs() < 1e-9, "{gate}");
        assert!(priced.predicted_throughput > unpriced.predicted_throughput);
        assert!(
            priced.render().contains("batch: B=8"),
            "{}",
            priced.render()
        );

        // A 25 ms latency budget only admits B with (B-1)*gate <= 25 ms:
        // B=3 waits ~2*10.something ms, feasible; B=4 is not.
        let bounded = plan(&mk(BatchCost {
            fixed_secs: 5e-3,
            max_batch: 8,
            latency_budget_secs: 25e-3,
        }))
        .unwrap();
        assert_eq!(bounded.batch, 3);

        // Zero overhead or max_batch 1 keeps the plan unbatched.
        let inert = plan(&mk(BatchCost {
            fixed_secs: 0.0,
            max_batch: 8,
            latency_budget_secs: 0.0,
        }))
        .unwrap();
        assert_eq!(inert.batch, 1);
    }

    #[test]
    fn batch_term_amortizes_across_replicas() {
        // Two equal stages, one of which the budget lets replicate: the
        // per-frame batch charge divides by R like the rest of the busy
        // time, so the lightly-replicated stage carries more of it.
        let p = PlacementProblem {
            stages: vec![
                StageCost {
                    flops: 20_000_000,
                    input_bytes: 1_000,
                    output_bytes: 1_000,
                },
                StageCost {
                    flops: 5_000_000,
                    input_bytes: 1_000,
                    output_bytes: 1_000,
                },
            ],
            devices: homogeneous(3, 1_000.0),
            worker_budget: 3,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost {
                fixed_secs: 4e-3,
                max_batch: 4,
                latency_budget_secs: 0.0,
            },
        };
        let plan = plan(&p).unwrap();
        assert!(plan.batch > 1, "batch stayed 1: {}", plan.render());
        // Same amortized per-frame charge on both stages...
        assert_eq!(plan.stages[0].batch, plan.stages[1].batch);
        // ...but stage 0 (replicated) spreads it over R service-wise.
        assert_eq!(plan.replica_counts(), vec![2, 1]);
    }

    #[test]
    fn budget_and_pool_validated() {
        let stages = vec![StageCost {
            flops: 1,
            input_bytes: 1,
            output_bytes: 1,
        }];
        let err = plan(&PlacementProblem {
            stages: stages.clone(),
            devices: homogeneous(1, 100.0),
            worker_budget: 0,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        })
        .unwrap_err();
        assert!(format!("{err}").contains("budget"));
        let err = plan(&PlacementProblem {
            stages,
            devices: homogeneous(1, 100.0),
            worker_budget: 3,
            uplink: LinkSpec::ideal(),
            interconnect: vec![],
            codec: CodecCost::default(),
            relay_junctions: false,
            batch: BatchCost::ZERO,
        })
        .unwrap_err();
        assert!(format!("{err}").contains("devices"));
    }
}
