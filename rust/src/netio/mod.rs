//! Sharded readiness-driven data plane (the reactor).
//!
//! The blocking data plane parks one thread per connection endpoint: a
//! worker at a `u -> d` boundary owns `u` reader + `d` writer sides, so
//! a replicated mesh costs `O(u + d)` threads per replica. The reactor
//! collapses all of that onto a small fixed pool of event-loop shards
//! (`--io-threads`, default `min(2, cores)`): every ingress (merge) and
//! egress (deal) endpoint set becomes one *state machine* registered
//! with a shard, and the shard steps machines only when their sources
//! report readiness.
//!
//! Two readiness sources feed a shard:
//!
//! * **epoll** — nonblocking TCP sockets, armed one-shot
//!   ([`sys::EPOLLONESHOT`]) for exactly the event the machine is
//!   blocked on. Every fd of a machine carries the machine's token, so
//!   any readiness steps the whole machine.
//! * **pipe wakers** — in-process [`crate::threadpool`] pipes (the
//!   `Conn::Local` transport and the machines' own hand-off pipes) fire
//!   a registered callback on data/space transitions. The callback
//!   pushes the machine's token onto the shard's ready queue and bumps
//!   the shard's eventfd, which lives in the same epoll set.
//!
//! # Schedule and byte-accounting parity
//!
//! The machines re-run the *identical* deal/merge schedules as the
//! blocking [`crate::topology::wiring`] endpoints: an ingress machine
//! reads only the connection that owns the next global frame (kernel
//! socket buffers and bounded pipes hold the rest, exactly like a
//! parked blocking reader), and an egress machine drains a FIFO queue
//! of `(conn, bytes)` pairs the producer serialized *in schedule
//! order*. Serialization, link shaping (which sleeps!) and byte
//! accounting all stay on the producer thread inside [`DealSink`] —
//! the shards move already-shaped bytes only — so wire traffic, byte
//! totals and per-frame metrics are bit-identical across planes.
//!
//! # Failure surfacing
//!
//! A machine that hits a wire error stashes a labelled
//! [`DeferError`] in its shared error slot and retires; dropping its
//! pipe endpoint unblocks the attached producer/consumer, which
//! collects the stashed error. Labels match the blocking plane's
//! (`send to {peer}: ...` / `recv from {peer}: ...`).

pub mod sys;

use std::collections::{HashMap, HashSet};
use std::io::Read;
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::transport::{ReadHalf, WriteHalf};
use crate::error::{DeferError, Result};
use crate::metrics::{zerocopy, ByteCounter};
use crate::netem::Link;
use crate::runtime::recovery::{ChunkRetryClient, RecoverySupervisor, RetentionRing};
use crate::threadpool::{pipe, PipeReceiver, PipeSender, TryRecv, TrySend};
use crate::topology::wiring::{frame_context, DealSender, MergeReceiver};
use crate::util::bufpool::BufPool;
use crate::wire::{
    write_message, FrameAssembler, Message, MessageType, SharedPayload, WireBuf, WireFrame,
};

/// `(is_data, frame, batch)` parsed off a serialized wire buffer's
/// header — the egress machine reports routing per *delivered* buffer,
/// and re-parses these three fields rather than threading a side
/// channel through its queue.
fn parse_buf_header(buf: &[u8]) -> Option<(bool, u64, u32)> {
    if buf.len() < crate::wire::HEADER_SIZE {
        return None;
    }
    let is_data = buf[4] == MessageType::Data as u8;
    let batch = 1 + u32::from_le_bytes([buf[5], buf[6], buf[7], 0]);
    let frame = u64::from_le_bytes(buf[8..16].try_into().ok()?);
    Some((is_data, frame, batch))
}

/// [`parse_buf_header`] over either [`WireBuf`] shape.
fn parse_wirebuf_header(buf: &WireBuf) -> Option<(bool, u64, u32)> {
    parse_buf_header(buf.wire_header()?)
}

/// Shared slot a machine stashes its terminal error in; the attached
/// producer/consumer takes it once the machine's pipe closes.
pub type ErrSlot = Arc<Mutex<Option<DeferError>>>;

/// Epoll token reserved for the shard's own eventfd.
const WAKE_TOKEN: u64 = u64::MAX;

// ------------------------------------------------------------- ShardSignal

/// Registration command queued to a shard.
enum Command {
    Attach { token: u64, machine: Machine },
}

/// The cross-thread face of one shard: wakers and registration threads
/// hold a strong `Arc` to it, so the eventfd stays open for as long as
/// anything might still signal it (fd reuse after close would otherwise
/// let a stale waker poke an unrelated fd).
struct ShardSignal {
    efd: RawFd,
    ready: Mutex<Vec<u64>>,
    commands: Mutex<Vec<Command>>,
    shutdown: AtomicBool,
    /// Monotonic machine-token allocator. Tokens are never reused, so a
    /// stale token in the ready queue (its machine already retired) is
    /// harmlessly skipped.
    next_token: AtomicU64,
}

impl ShardSignal {
    fn wake(&self) {
        sys::eventfd_signal(self.efd);
    }

    fn push_ready(&self, token: u64) {
        self.ready.lock().unwrap().push(token);
        self.wake();
    }

    fn attach(&self, token: u64, machine: Machine) {
        self.commands
            .lock()
            .unwrap()
            .push(Command::Attach { token, machine });
        self.wake();
    }
}

impl Drop for ShardSignal {
    fn drop(&mut self) {
        sys::close_fd(self.efd);
    }
}

/// Per-shard activity counters (exposed via [`Reactor::shard_stats`]).
#[derive(Default)]
struct ShardStats {
    wakeups: AtomicU64,
    dispatches: AtomicU64,
}

// ----------------------------------------------------------- state machines

enum Step {
    Idle,
    Done,
}

enum Machine {
    Ingress(IngressMachine),
    Egress(EgressMachine),
}

impl Machine {
    fn tcp_fds(&self) -> Vec<RawFd> {
        match self {
            Machine::Ingress(m) => m
                .conns
                .iter()
                .filter_map(|c| match &c.io {
                    IngressIo::Tcp { stream, .. } => Some(stream.as_raw_fd()),
                    IngressIo::Local { .. } => None,
                })
                .collect(),
            Machine::Egress(m) => m
                .conns
                .iter()
                .filter_map(|c| match &c.io {
                    EgressIo::Tcp { stream } => Some(stream.as_raw_fd()),
                    EgressIo::Local { .. } => None,
                })
                .collect(),
        }
    }

    fn step(&mut self, epfd: RawFd, token: u64) -> Step {
        match self {
            Machine::Ingress(m) => m.step(epfd, token),
            Machine::Egress(m) => m.step(epfd, token),
        }
    }
}

// ----------------------------------------------------------------- ingress

/// One merge-side connection adopted by the reactor.
struct IngressConn {
    io: IngressIo,
    label: String,
}

enum IngressIo {
    Tcp {
        stream: TcpStream,
        /// Bytes the pre-split buffered reader had already consumed off
        /// the socket; served to the assembler before fresh reads.
        residue: Vec<u8>,
        asm: FrameAssembler,
    },
    Local {
        rx: PipeReceiver<WireBuf>,
        pending: Vec<u8>,
        frames: Arc<BufPool>,
    },
}

enum IngressState {
    /// Normal operation: read the scheduled connection only.
    Running,
    /// Scheduled conn delivered `Shutdown`; read the one pending
    /// `Shutdown` off every other conn (the deal invariant guarantees
    /// they hold nothing else) before forwarding the merged marker.
    Draining {
        drained: Vec<bool>,
        pending: Option<Message>,
    },
    /// Recovery mode after an observed death: arrival-order merge over
    /// every conn not yet resolved (`done` = Shutdown seen or peer
    /// dead), deduplicated via the machine's `seen` set. Ends in one
    /// merged `Shutdown` once every conn resolved with at least one
    /// clean Shutdown.
    Degraded { done: Vec<bool>, shutdowns: usize },
    /// Merged `Shutdown` parked/flushed; close the pipe and retire.
    Finishing,
}

/// Schedule-preserving merge as a state machine: reads only the conn
/// that owns the next global frame, forwards complete messages into a
/// bounded pipe, parks on pipe backpressure, and reproduces the
/// blocking [`MergeReceiver`]'s shutdown drain and error labels. With a
/// supervisor attached, any observed replica death (its own conns or a
/// death elsewhere bumping the epoch) degrades a replicated merge to
/// arrival order, mirroring [`MergeReceiver`]'s degraded mode.
struct IngressMachine {
    conns: Vec<IngressConn>,
    next: usize,
    step_by: usize,
    out: PipeSender<Message>,
    parked: Option<Message>,
    pool: Option<Arc<BufPool>>,
    err: ErrSlot,
    state: IngressState,
    recovery: Option<Arc<RecoverySupervisor>>,
    client: Option<Arc<ChunkRetryClient>>,
    /// Frames already forwarded (recovery mode only): re-dispatch can
    /// duplicate frames and duplicates must not be forwarded twice.
    seen: HashSet<u64>,
    /// Last global frame forwarded (error context).
    last_frame: Option<u64>,
}

impl IngressMachine {
    /// Note bookkeeping for a data message about to be forwarded.
    /// Returns false when the frame is a re-dispatched duplicate that
    /// must be dropped instead.
    fn admit(&mut self, idx: usize, msg: &Message) -> bool {
        if self.recovery.is_some() && self.conns.len() > 1 && !self.seen.insert(msg.frame) {
            return false;
        }
        if let Some(client) = &self.client {
            client.note_provenance(msg.frame, &self.conns[idx].label);
        }
        self.last_frame = Some(msg.frame + u64::from(msg.batch.saturating_sub(1)));
        true
    }

    fn step(&mut self, epfd: RawFd, token: u64) -> Step {
        loop {
            // Flush a message the full pipe parked on a previous step.
            if let Some(msg) = self.parked.take() {
                match self.out.try_send(msg) {
                    TrySend::Ok => {}
                    TrySend::Full(m) => {
                        self.parked = Some(m);
                        return Step::Idle; // space waker re-steps us
                    }
                    // Consumer gone (teardown): finish quietly, like a
                    // blocked reader thread whose pipe send fails last.
                    TrySend::Closed(_) => return Step::Done,
                }
            }
            if matches!(self.state, IngressState::Finishing) {
                self.out.close();
                return Step::Done;
            }
            if matches!(self.state, IngressState::Running) {
                // A death anywhere in the mesh scrambles global arrival
                // order, so the positional schedule stops being
                // trustworthy: switch to arrival order. The supervisor's
                // registered waker re-steps this machine on mark_dead.
                if let Some(sup) = &self.recovery {
                    if self.conns.len() > 1 && sup.death_epoch() > 0 {
                        self.state = IngressState::Degraded {
                            done: vec![false; self.conns.len()],
                            shutdowns: 0,
                        };
                        continue;
                    }
                }
                let idx = self.next;
                match self.poll_conn(idx, epfd, token) {
                    Err(e) => {
                        if let Some(sup) = self.recovery.clone() {
                            if self.conns.len() > 1 {
                                // Scheduled predecessor died: survivable.
                                sup.mark_dead(&self.conns[idx].label);
                                let mut done = vec![false; self.conns.len()];
                                done[idx] = true;
                                self.state = IngressState::Degraded { done, shutdowns: 0 };
                                continue;
                            }
                        }
                        return self.fail(idx, e);
                    }
                    Ok(None) => return Step::Idle,
                    Ok(Some(msg)) => {
                        if msg.msg_type == MessageType::Shutdown {
                            let mut drained = vec![false; self.conns.len()];
                            drained[idx] = true;
                            self.state = IngressState::Draining {
                                drained,
                                pending: Some(msg),
                            };
                        } else {
                            self.next = (self.next + self.step_by) % self.conns.len();
                            if self.admit(idx, &msg) {
                                self.parked = Some(msg);
                            }
                        }
                    }
                }
                continue;
            }
            if matches!(self.state, IngressState::Degraded { .. }) {
                let (mut done, mut shutdowns) =
                    match std::mem::replace(&mut self.state, IngressState::Finishing) {
                        IngressState::Degraded { done, shutdowns } => (done, shutdowns),
                        _ => unreachable!("only Degraded reaches here"),
                    };
                let mut forwarded = None;
                let mut blocked = false;
                'scan: for i in 0..self.conns.len() {
                    if done[i] {
                        continue;
                    }
                    match self.poll_conn(i, epfd, token) {
                        Err(_) => {
                            // Another death: report it, keep merging the
                            // survivors.
                            if let Some(sup) = &self.recovery {
                                sup.mark_dead(&self.conns[i].label);
                            }
                            done[i] = true;
                        }
                        Ok(None) => blocked = true,
                        Ok(Some(m)) => {
                            if m.msg_type == MessageType::Shutdown {
                                done[i] = true;
                                shutdowns += 1;
                            } else if self.admit(i, &m) {
                                forwarded = Some(m);
                                break 'scan;
                            }
                        }
                    }
                }
                if let Some(m) = forwarded {
                    self.parked = Some(m);
                    self.state = IngressState::Degraded { done, shutdowns };
                    continue;
                }
                if done.iter().all(|&d| d) {
                    if shutdowns == 0 {
                        return self.fail_raw(DeferError::Coordinator(format!(
                            "recv{}: no live predecessor remains",
                            frame_context(self.last_frame)
                        )));
                    }
                    // state is already Finishing; park the merged marker.
                    self.parked = Some(Message::control(MessageType::Shutdown));
                    continue;
                }
                self.state = IngressState::Degraded { done, shutdowns };
                if blocked {
                    return Step::Idle;
                }
                continue;
            }
            // Draining: collect one Shutdown from every remaining conn.
            // Order across conns is irrelevant (each holds exactly one
            // final message), so all blocked conns stay armed at once.
            let (mut drained, mut pending) =
                match std::mem::replace(&mut self.state, IngressState::Finishing) {
                    IngressState::Draining { drained, pending } => (drained, pending),
                    _ => unreachable!("only Draining reaches here"),
                };
            let mut blocked = false;
            for i in 0..self.conns.len() {
                if drained[i] {
                    continue;
                }
                match self.poll_conn(i, epfd, token) {
                    Err(e) => {
                        // With a supervisor a peer may die between its
                        // last frame and its Shutdown; the stream is
                        // already complete, so report the death and keep
                        // draining the rest.
                        if let Some(sup) = &self.recovery {
                            sup.mark_dead(&self.conns[i].label);
                            drained[i] = true;
                            continue;
                        }
                        return self.fail(i, e);
                    }
                    Ok(None) => blocked = true,
                    Ok(Some(m)) => {
                        if m.msg_type == MessageType::Shutdown {
                            drained[i] = true;
                        } else if self.recovery.is_some() {
                            // A re-dispatched duplicate still in flight
                            // when the stream completed: drop it and
                            // keep draining toward this conn's Shutdown.
                        } else {
                            return self.fail_raw(DeferError::Coordinator(format!(
                                "{} sent {:?} after the merged stream ended",
                                self.conns[i].label, m.msg_type
                            )));
                        }
                    }
                }
            }
            if blocked {
                self.state = IngressState::Draining { drained, pending };
                return Step::Idle;
            }
            self.parked = pending.take();
            // state is already Finishing; loop flushes the parked marker.
        }
    }

    /// Try to produce one complete message from conn `idx`. `Ok(None)`
    /// means the source would block (and, for TCP, the fd has been
    /// re-armed for the machine's token). Errors are unlabelled; the
    /// caller wraps them with the peer label.
    fn poll_conn(&mut self, idx: usize, epfd: RawFd, token: u64) -> Result<Option<Message>> {
        let pool = self.pool.clone();
        let conn = &mut self.conns[idx];
        match &mut conn.io {
            IngressIo::Tcp {
                stream,
                residue,
                asm,
            } => {
                let res = {
                    let sock = &*stream;
                    let mut read = |buf: &mut [u8]| -> std::io::Result<usize> {
                        if !residue.is_empty() {
                            let n = residue.len().min(buf.len());
                            buf[..n].copy_from_slice(&residue[..n]);
                            residue.drain(..n);
                            return Ok(n);
                        }
                        let mut s: &TcpStream = sock;
                        s.read(buf)
                    };
                    asm.poll(&mut read, pool.as_deref())
                };
                match res {
                    Ok(Some(msg)) => Ok(Some(msg)),
                    Ok(None) => {
                        sys::epoll_mod(
                            epfd,
                            stream.as_raw_fd(),
                            sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLONESHOT,
                            token,
                        )?;
                        Ok(None)
                    }
                    Err(e) => Err(e),
                }
            }
            IngressIo::Local {
                rx,
                pending,
                frames,
            } => {
                if pending.is_empty() {
                    match rx.try_recv() {
                        // Zero-copy fast path: a shared frame delivers its
                        // pooled payload straight into the message (the
                        // sender already verified + counted the hop).
                        TryRecv::Item(WireBuf::Frame(wf)) => return Ok(Some(wf.into_message())),
                        TryRecv::Item(WireBuf::Raw(buf)) => *pending = buf,
                        // The permanent data waker re-steps us on arrival.
                        TryRecv::Empty => return Ok(None),
                        TryRecv::Closed => {
                            return Err(DeferError::ChannelClosed("local conn recv"))
                        }
                    }
                }
                // Mirror `Conn::recv_pooled`: parse one message off the
                // pending buffer (receive side always uses a throwaway
                // counter — the sender already counted the hop).
                let mut cursor = std::io::Cursor::new(pending.as_slice());
                let msg =
                    crate::wire::read_message_pooled(&mut cursor, &ByteCounter::new(), pool.as_deref())?;
                let consumed = cursor.position() as usize;
                pending.drain(..consumed);
                if pending.is_empty() {
                    frames.put(std::mem::take(pending));
                }
                Ok(Some(msg))
            }
        }
    }

    fn fail(&mut self, idx: usize, e: DeferError) -> Step {
        let label = &self.conns[idx].label;
        let ctx = frame_context(self.last_frame);
        self.fail_raw(DeferError::Coordinator(format!(
            "recv from {label}{ctx}: {e}"
        )))
    }

    fn fail_raw(&mut self, e: DeferError) -> Step {
        *self.err.lock().unwrap() = Some(e);
        self.out.close();
        Step::Done
    }
}

// ------------------------------------------------------------------ egress

/// One deal-side connection adopted by the reactor.
struct EgressConn {
    io: EgressIo,
    label: String,
}

enum EgressIo {
    Tcp { stream: TcpStream },
    Local { tx: PipeSender<WireBuf> },
}

enum WriteOut {
    Flushed,
    Pending(WireBuf, usize),
    /// The buffer comes back with the error so a recovering machine can
    /// reroute it to a surviving successor.
    Failed(WireBuf, DeferError),
}

/// Drains a FIFO queue of pre-serialized `(conn, bytes)` buffers onto
/// the wire, resuming partial TCP writes across readiness events. FIFO
/// consumption preserves the producer's schedule order exactly.
///
/// With a supervisor attached, a failed write marks the peer dead and
/// reroutes the buffer to the next live successor (a control buffer
/// destined to a dead peer is dropped instead — shutdown markers are
/// per-conn, not per-frame), and every delivered data buffer is
/// reported to the supervisor as owed by its actual recipient.
struct EgressMachine {
    queue: PipeReceiver<(usize, WireBuf)>,
    conns: Vec<EgressConn>,
    /// A buffer mid-write: `(conn idx, bytes, bytes already written)`.
    /// The offset is logical over `header ‖ payload`.
    in_flight: Option<(usize, WireBuf, usize)>,
    err: ErrSlot,
    recovery: Option<Arc<RecoverySupervisor>>,
    /// Last global frame flushed (error context).
    last_frame: Option<u64>,
}

impl EgressMachine {
    fn step(&mut self, epfd: RawFd, token: u64) -> Step {
        loop {
            if let Some((idx, buf, written)) = self.in_flight.take() {
                // Parse before the write: a successful local send moves
                // the buffer into the pipe.
                let hdr = parse_wirebuf_header(&buf);
                match write_step(&mut self.conns[idx], epfd, token, buf, written) {
                    WriteOut::Flushed => {
                        if let Some((true, frame, batch)) = hdr {
                            if let Some(sup) = &self.recovery {
                                sup.note_routed(&self.conns[idx].label, frame, batch);
                            }
                            self.last_frame = Some(frame + u64::from(batch.saturating_sub(1)));
                        }
                    }
                    WriteOut::Pending(buf, written) => {
                        self.in_flight = Some((idx, buf, written));
                        return Step::Idle;
                    }
                    WriteOut::Failed(buf, e) => match self.reroute(idx, buf, e) {
                        Ok(()) => {}
                        Err(step) => return step,
                    },
                }
            }
            match self.queue.try_recv() {
                TryRecv::Item((idx, buf)) => {
                    // A buffer scheduled to an already-dead successor is
                    // redirected (data) or dropped (control) up front.
                    let dead = self
                        .recovery
                        .as_ref()
                        .map(|sup| sup.is_dead(&self.conns[idx].label))
                        .unwrap_or(false);
                    if dead {
                        match self.reroute(idx, buf, DeferError::ChannelClosed("peer dead")) {
                            Ok(()) => {}
                            Err(step) => return step,
                        }
                    } else {
                        self.in_flight = Some((idx, buf, 0));
                    }
                }
                // The queue's data waker re-steps us on the next enqueue.
                TryRecv::Empty => return Step::Idle,
                // Producer done and everything flushed: retire.
                TryRecv::Closed => return Step::Done,
            }
        }
    }

    /// A write to `idx` failed (or `idx` is known dead). Without a
    /// supervisor this retires the machine with a labelled error; with
    /// one, the peer is marked dead and a data buffer moves to the next
    /// live successor (control buffers are dropped — already delivered
    /// per-conn to the survivors).
    fn reroute(&mut self, idx: usize, buf: WireBuf, e: DeferError) -> std::result::Result<(), Step> {
        let Some(sup) = self.recovery.clone() else {
            return Err(self.fail(idx, e));
        };
        sup.mark_dead(&self.conns[idx].label);
        let is_data = matches!(parse_wirebuf_header(&buf), Some((true, _, _)));
        if !is_data {
            return Ok(());
        }
        let n = self.conns.len();
        let live = (0..n)
            .map(|k| (idx + 1 + k) % n)
            .find(|&j| !sup.is_dead(&self.conns[j].label));
        match live {
            Some(j) => {
                self.in_flight = Some((j, buf, 0));
                Ok(())
            }
            None => Err(self.fail_raw(DeferError::Coordinator(format!(
                "send to {}{}: all {n} successors dead: {e}",
                self.conns[idx].label,
                frame_context(self.last_frame)
            )))),
        }
    }

    /// Stash a labelled error and retire. Dropping the machine drops the
    /// queue receiver, so the producer's next enqueue fails and it
    /// collects the stashed error from the slot.
    fn fail(&mut self, idx: usize, e: DeferError) -> Step {
        let label = &self.conns[idx].label;
        let ctx = frame_context(self.last_frame);
        self.fail_raw(DeferError::Coordinator(format!(
            "send to {label}{ctx}: {e}"
        )))
    }

    fn fail_raw(&mut self, e: DeferError) -> Step {
        *self.err.lock().unwrap() = Some(e);
        Step::Done
    }
}

/// Push as much of `buf` as the conn accepts. TCP would-block arms
/// `EPOLLOUT` one-shot; a full local pipe relies on its space waker.
///
/// A [`WireBuf::Frame`] gather-writes header + payload in **one**
/// `writev` syscall (no assemble copy); the logical `written` offset
/// spans `header ‖ payload`, so a short write resumes mid-header,
/// mid-payload, or exactly at the iovec boundary. Every `writev` issued
/// bumps the `egress_syscalls` counter.
fn write_step(
    conn: &mut EgressConn,
    epfd: RawFd,
    token: u64,
    buf: WireBuf,
    mut written: usize,
) -> WriteOut {
    enum TcpOut {
        Flushed,
        Blocked,
        Err(DeferError),
    }
    match &mut conn.io {
        EgressIo::Tcp { stream } => {
            let fd = stream.as_raw_fd();
            let out = {
                let (head, body): (&[u8], &[u8]) = match &buf {
                    WireBuf::Frame(wf) => (wf.header_bytes(), wf.payload_bytes()),
                    WireBuf::Raw(b) => (b.as_slice(), &[]),
                };
                let total = head.len() + body.len();
                loop {
                    if written == total {
                        break TcpOut::Flushed;
                    }
                    let res = if written < head.len() {
                        sys::writev2(fd, &head[written..], body)
                    } else {
                        sys::writev2(fd, &body[written - head.len()..], &[])
                    };
                    zerocopy::count_egress_syscall();
                    match res {
                        Ok(0) => {
                            break TcpOut::Err(DeferError::Io(
                                std::io::ErrorKind::WriteZero.into(),
                            ))
                        }
                        Ok(n) => written += n,
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            break match sys::epoll_mod(
                                epfd,
                                fd,
                                sys::EPOLLOUT | sys::EPOLLONESHOT,
                                token,
                            ) {
                                Ok(()) => TcpOut::Blocked,
                                Err(e) => TcpOut::Err(e.into()),
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        Err(e) => break TcpOut::Err(e.into()),
                    }
                }
            };
            match out {
                TcpOut::Flushed => WriteOut::Flushed,
                TcpOut::Blocked => WriteOut::Pending(buf, written),
                TcpOut::Err(e) => WriteOut::Failed(buf, e),
            }
        }
        EgressIo::Local { tx } => match tx.try_send(buf) {
            TrySend::Ok => WriteOut::Flushed,
            TrySend::Full(b) => WriteOut::Pending(b, 0),
            TrySend::Closed(b) => {
                WriteOut::Failed(b, DeferError::ChannelClosed("local conn send"))
            }
        },
    }
}

// ---------------------------------------------------------------- DealSink

/// Producer-side handle for a reactor-registered egress set: the
/// blocking [`DealSender`]'s API, but `send_data` serializes, shapes and
/// counts on *this* thread and enqueues the finished bytes for the
/// shard to write. The bounded queue is the backpressure window.
pub struct DealSink {
    queue: PipeSender<(usize, WireBuf)>,
    labels: Vec<String>,
    next: usize,
    step: usize,
    err: ErrSlot,
    recovery: Option<Arc<RecoverySupervisor>>,
    ring: Option<Arc<RetentionRing>>,
    last_frame: Option<u64>,
}

impl DealSink {
    /// Number of successor connections.
    pub fn fan(&self) -> usize {
        self.labels.len()
    }

    /// Serialized messages not yet handed to the wire (adaptive-batching
    /// signal, same role as the encoder pipe depth).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Send one data message per the deal schedule (see
    /// [`DealSender::send_data`]). Shaping sleeps and byte accounting
    /// happen here, before the enqueue, so metrics and pacing are
    /// identical to the blocking plane.
    pub fn send_data(&mut self, msg: &Message, link: &Link, counter: &ByteCounter) -> Result<()> {
        let scheduled = self.next;
        self.next = (self.next + self.step) % self.labels.len();
        // Redirect a send scheduled to an already-dead successor before
        // serialization so the shaped/counted copy targets a live peer
        // (the machine re-checks at dequeue for deaths that land later).
        let idx = match &self.recovery {
            None => scheduled,
            Some(sup) => {
                let n = self.labels.len();
                match (0..n)
                    .map(|k| (scheduled + k) % n)
                    .find(|&j| !sup.is_dead(&self.labels[j]))
                {
                    Some(j) => j,
                    None => {
                        return Err(DeferError::Coordinator(format!(
                            "send to {}{}: all {n} successors dead",
                            self.labels[scheduled],
                            frame_context(self.last_frame)
                        )))
                    }
                }
            }
        };
        let mut buf = Vec::with_capacity(msg.wire_size() as usize);
        write_message(&mut buf, msg, link, counter)?;
        if !msg.payload.is_empty() {
            zerocopy::count_payload_copy();
        }
        if self.queue.send((idx, WireBuf::Raw(buf))).is_err() {
            return Err(self.writer_error(idx));
        }
        if msg.msg_type == MessageType::Data {
            if let Some(ring) = &self.ring {
                zerocopy::count_payload_copy();
                ring.push(msg.frame, SharedPayload::from_vec(msg.payload.clone(), None));
            }
            self.last_frame = Some(msg.frame + u64::from(msg.batch.saturating_sub(1)));
        }
        Ok(())
    }

    /// Zero-copy counterpart of [`DealSink::send_data`]: the encoder
    /// already produced the frame's wire form once, so shaping sleeps
    /// and byte accounting happen here (identical byte sequence to the
    /// serialize path) and the *shared* buffer is enqueued for the shard
    /// to gather-write — no serialize copy, and the retention ring holds
    /// another reference to the same payload instead of a clone.
    pub fn send_frame(&mut self, wf: WireFrame, link: &Link, counter: &ByteCounter) -> Result<()> {
        let scheduled = self.next;
        self.next = (self.next + self.step) % self.labels.len();
        let idx = match &self.recovery {
            None => scheduled,
            Some(sup) => {
                let n = self.labels.len();
                match (0..n)
                    .map(|k| (scheduled + k) % n)
                    .find(|&j| !sup.is_dead(&self.labels[j]))
                {
                    Some(j) => j,
                    None => {
                        return Err(DeferError::Coordinator(format!(
                            "send to {}{}: all {n} successors dead",
                            self.labels[scheduled],
                            frame_context(self.last_frame)
                        )))
                    }
                }
            }
        };
        wf.charge(link, counter);
        let routed = (wf.msg_type() == MessageType::Data).then(|| (wf.frame(), wf.batch()));
        if routed.is_some() {
            if let Some(ring) = &self.ring {
                ring.push(wf.frame(), wf.shared_payload().clone());
            }
        }
        if self.queue.send((idx, WireBuf::Frame(wf))).is_err() {
            return Err(self.writer_error(idx));
        }
        if let Some((frame, batch)) = routed {
            self.last_frame = Some(frame + u64::from(batch.saturating_sub(1)));
        }
        Ok(())
    }

    /// Broadcast `Shutdown` to every successor with the blocking plane's
    /// byte accounting: one shaped/counted copy (the first live
    /// successor — index 0 when nothing died), the fan-out rest over an
    /// ideal link into a throwaway counter. Dead successors are skipped.
    pub fn broadcast_shutdown(&mut self, link: &Link, counter: &ByteCounter) -> Result<()> {
        let msg = Message::control(MessageType::Shutdown);
        let null = ByteCounter::new();
        let ideal = Link::ideal();
        let mut counted = false;
        for idx in 0..self.labels.len() {
            if let Some(sup) = &self.recovery {
                if sup.is_dead(&self.labels[idx]) {
                    continue;
                }
            }
            let (l, c) = if counted { (&ideal, &null) } else { (link, counter) };
            counted = true;
            let mut buf = Vec::with_capacity(msg.wire_size() as usize);
            write_message(&mut buf, &msg, l, c)?;
            if self.queue.send((idx, WireBuf::Raw(buf))).is_err() {
                let e = self.writer_error(idx);
                return Err(DeferError::Coordinator(format!(
                    "shutdown broadcast failed: {e}"
                )));
            }
        }
        Ok(())
    }

    /// Fault injection: enqueue the first `n` bytes of `msg`'s wire
    /// encoding (at least 1, at most all-but-one) toward the scheduled
    /// successor. The caller dies next, so the machine flushes the
    /// partial message and the conns close — the peer observes a
    /// mid-message EOF, same as the blocking plane.
    pub fn send_truncated(&mut self, msg: &Message, n: usize) -> Result<()> {
        let idx = self.next;
        let mut buf = Vec::with_capacity(msg.wire_size() as usize);
        write_message(&mut buf, msg, &Link::ideal(), &ByteCounter::new())?;
        buf.truncate(n.clamp(1, buf.len().saturating_sub(1)));
        if self.queue.send((idx, WireBuf::Raw(buf))).is_err() {
            return Err(self.writer_error(idx));
        }
        Ok(())
    }

    /// The queue closed under us: the writer machine retired. Prefer its
    /// stashed (labelled) error; a missing slot means plain teardown.
    fn writer_error(&self, idx: usize) -> DeferError {
        self.err.lock().unwrap().take().unwrap_or_else(|| {
            DeferError::Coordinator(format!(
                "send to {}: data-plane writer retired",
                self.labels[idx]
            ))
        })
    }
}

// ----------------------------------------------------------------- Reactor

struct Shard {
    signal: Arc<ShardSignal>,
    stats: Arc<ShardStats>,
    thread: Option<JoinHandle<()>>,
}

/// The sharded event-loop runtime. Create once per deployment, register
/// every data-plane endpoint set, and drop after the run drains (drop
/// joins the shard threads). Registrations round-robin across shards.
pub struct Reactor {
    shards: Vec<Shard>,
    next_shard: AtomicUsize,
}

impl Reactor {
    /// Spawn `io_threads` shard event loops (at least one).
    pub fn new(io_threads: usize) -> Result<Arc<Reactor>> {
        let n = io_threads.max(1);
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let efd = sys::eventfd_new()?;
            let signal = Arc::new(ShardSignal {
                efd,
                ready: Mutex::new(Vec::new()),
                commands: Mutex::new(Vec::new()),
                shutdown: AtomicBool::new(false),
                next_token: AtomicU64::new(0),
            });
            let stats = Arc::new(ShardStats::default());
            let (sig, st) = (Arc::clone(&signal), Arc::clone(&stats));
            let thread = std::thread::Builder::new()
                .name(format!("netio-shard{i}"))
                .spawn(move || run_shard(sig, st))
                .map_err(DeferError::Io)?;
            shards.push(Shard {
                signal,
                stats,
                thread: Some(thread),
            });
        }
        Ok(Arc::new(Reactor {
            shards,
            next_shard: AtomicUsize::new(0),
        }))
    }

    /// Default shard count: `min(2, cores)` — mesh I/O is memcpy-bound,
    /// two shards saturate loopback while keeping the thread bill fixed.
    pub fn default_io_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(2)
    }

    /// Number of shard threads.
    pub fn io_threads(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard `(wakeups, dispatches)` counters: epoll returns and
    /// machine steps, respectively.
    pub fn shard_stats(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| {
                (
                    s.stats.wakeups.load(Ordering::Relaxed),
                    s.stats.dispatches.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    fn pick_shard(&self) -> &Shard {
        let i = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        &self.shards[i]
    }

    /// Adopt a merge set: the machine feeds the identical in-order
    /// message stream (ending in one merged `Shutdown`) into `out`, then
    /// closes it. A machine failure closes `out` early and parks the
    /// labelled error in the returned slot.
    pub fn register_ingress(
        &self,
        source: MergeReceiver,
        out: PipeSender<Message>,
        pool: Option<Arc<BufPool>>,
    ) -> Result<ErrSlot> {
        let shard = self.pick_shard();
        let token = shard.signal.next_token.fetch_add(1, Ordering::Relaxed);
        let waker: Arc<dyn Fn() + Send + Sync> = {
            let sig = Arc::clone(&shard.signal);
            Arc::new(move || sig.push_ready(token))
        };
        let recovery = source.recovery_handle();
        let client = source.chunk_client();
        if let Some(sup) = &recovery {
            // A death observed anywhere (even by a blocking endpoint)
            // must re-step this machine so it notices the epoch bump and
            // degrades its schedule.
            sup.register_waker(Arc::clone(&waker));
        }
        let (conns, labels, next, step) = source.into_parts();
        let mut iconns = Vec::with_capacity(conns.len());
        for (conn, label) in conns.into_iter().zip(labels) {
            let io = match conn.into_read_half_pooled(pool.as_deref())? {
                ReadHalf::Tcp { stream, residue } => IngressIo::Tcp {
                    stream,
                    residue,
                    asm: FrameAssembler::new(),
                },
                ReadHalf::Local {
                    rx,
                    pending,
                    frames,
                } => {
                    rx.set_data_waker(Arc::clone(&waker));
                    IngressIo::Local {
                        rx,
                        pending,
                        frames,
                    }
                }
            };
            iconns.push(IngressConn { io, label });
        }
        out.set_space_waker(Arc::clone(&waker));
        let err: ErrSlot = Arc::new(Mutex::new(None));
        let machine = Machine::Ingress(IngressMachine {
            conns: iconns,
            next,
            step_by: step,
            out,
            parked: None,
            pool,
            err: Arc::clone(&err),
            state: IngressState::Running,
            recovery,
            client,
            seen: HashSet::new(),
            last_frame: None,
        });
        shard.signal.attach(token, machine);
        Ok(err)
    }

    /// Adopt a deal set: returns the producer-side [`DealSink`] whose
    /// bounded queue (`depth` messages) replaces the inline blocking
    /// writes as the backpressure window.
    pub fn register_egress(&self, sender: DealSender, depth: usize) -> Result<DealSink> {
        let shard = self.pick_shard();
        let token = shard.signal.next_token.fetch_add(1, Ordering::Relaxed);
        let waker: Arc<dyn Fn() + Send + Sync> = {
            let sig = Arc::clone(&shard.signal);
            Arc::new(move || sig.push_ready(token))
        };
        let recovery = sender.recovery_handle();
        let ring = sender.retention_handle();
        if let Some(sup) = &recovery {
            // Deaths observed elsewhere must re-step this machine: a
            // queued buffer destined to the dead peer needs rerouting
            // even when no fd reports readiness.
            sup.register_waker(Arc::clone(&waker));
        }
        let (conns, labels, next, step) = sender.into_parts();
        let (queue_tx, queue_rx) = pipe::<(usize, WireBuf)>(depth.max(1));
        queue_rx.set_data_waker(Arc::clone(&waker));
        let mut econns = Vec::with_capacity(conns.len());
        for (conn, label) in conns.into_iter().zip(labels.iter()) {
            let io = match conn.into_write_half()? {
                WriteHalf::Tcp { stream } => EgressIo::Tcp { stream },
                WriteHalf::Local { tx, .. } => {
                    tx.set_space_waker(Arc::clone(&waker));
                    EgressIo::Local { tx }
                }
            };
            econns.push(EgressConn {
                io,
                label: label.clone(),
            });
        }
        let err: ErrSlot = Arc::new(Mutex::new(None));
        let machine = Machine::Egress(EgressMachine {
            queue: queue_rx,
            conns: econns,
            in_flight: None,
            err: Arc::clone(&err),
            recovery: recovery.clone(),
            last_frame: None,
        });
        shard.signal.attach(token, machine);
        Ok(DealSink {
            queue: queue_tx,
            labels,
            next,
            step,
            err,
            recovery,
            ring,
            last_frame: None,
        })
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        for s in &self.shards {
            s.signal.shutdown.store(true, Ordering::Release);
            s.signal.wake();
        }
        for s in &mut self.shards {
            if let Some(t) = s.thread.take() {
                let _ = t.join();
            }
        }
    }
}

// -------------------------------------------------------------- shard loop

fn run_shard(signal: Arc<ShardSignal>, stats: Arc<ShardStats>) {
    let epfd = match sys::epoll_create() {
        Ok(fd) => fd,
        Err(_) => return,
    };
    // The eventfd is level-triggered: wakes queued while we're stepping
    // machines are observed by the next wait, so no wakeup is ever lost.
    if sys::epoll_add(epfd, signal.efd, sys::EPOLLIN, WAKE_TOKEN).is_err() {
        sys::close_fd(epfd);
        return;
    }
    let mut machines: HashMap<u64, Machine> = HashMap::new();
    let mut run_queue: Vec<u64> = Vec::new();
    let mut events = [sys::EpollEvent { events: 0, data: 0 }; 64];
    loop {
        // Adopt newly registered machines. Their TCP fds enter the wait
        // set disarmed-one-shot (no IN/OUT interest; the implicit
        // ERR/HUP delivery is one-shot too, so a dead fd cannot storm),
        // and the machine runs once immediately to make initial
        // progress and arm what it blocks on.
        let commands = std::mem::take(&mut *signal.commands.lock().unwrap());
        for Command::Attach { token, machine } in commands {
            for fd in machine.tcp_fds() {
                let _ = sys::epoll_add(epfd, fd, sys::EPOLLONESHOT, token);
            }
            machines.insert(token, machine);
            run_queue.push(token);
        }
        // Collect tokens pushed by pipe wakers, fold in epoll readiness
        // carried over from the previous wait, and step each machine
        // once per batch.
        run_queue.extend(std::mem::take(&mut *signal.ready.lock().unwrap()));
        run_queue.sort_unstable();
        run_queue.dedup();
        for token in run_queue.drain(..) {
            if let Some(m) = machines.get_mut(&token) {
                stats.dispatches.fetch_add(1, Ordering::Relaxed);
                if matches!(m.step(epfd, token), Step::Done) {
                    // Dropping the machine closes its conns; closed fds
                    // leave the epoll set automatically.
                    machines.remove(&token);
                }
            }
        }
        if signal.shutdown.load(Ordering::Acquire) {
            break;
        }
        let n = match sys::epoll_pwait(epfd, &mut events, -1) {
            Ok(n) => n,
            Err(_) => break,
        };
        stats.wakeups.fetch_add(1, Ordering::Relaxed);
        for ev in events.iter().take(n) {
            // Copy out of the (possibly packed) struct before use.
            let data = { ev.data };
            if data == WAKE_TOKEN {
                sys::eventfd_drain(signal.efd);
            } else {
                run_queue.push(data);
            }
        }
    }
    drop(machines);
    sys::close_fd(epfd);
}

// ------------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::Conn;

    fn data_msg(frame: u64) -> Message {
        Message {
            msg_type: MessageType::Data,
            frame,
            serialized_len: 4,
            count: 0,
            batch: 1,
            payload: vec![frame as u8; 4],
        }
    }

    #[test]
    fn ingress_restores_round_robin_order_over_local_conns() {
        let reactor = Reactor::new(2).unwrap();
        let u = 3;
        let mut up = Vec::new();
        let mut ins = Vec::new();
        for _ in 0..u {
            let (a, b) = Conn::local_pair(8);
            up.push(a);
            ins.push(b);
        }
        let labels = (0..u).map(|i| format!("peer{i}")).collect();
        let merge = MergeReceiver::new(ins, labels, 0, 1);
        let (tx, rx) = pipe::<Message>(4);
        let err = reactor.register_ingress(merge, tx, None).unwrap();
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..7u64 {
            up[(f as usize) % u].send(&data_msg(f), &link, &c).unwrap();
        }
        for conn in up.iter_mut() {
            conn.send(&Message::control(MessageType::Shutdown), &link, &c)
                .unwrap();
        }
        for f in 0..7u64 {
            assert_eq!(rx.recv().unwrap().frame, f);
        }
        assert_eq!(rx.recv().unwrap().msg_type, MessageType::Shutdown);
        assert!(rx.recv().is_none(), "pipe closes after the merged marker");
        assert!(err.lock().unwrap().is_none());
        let stats = reactor.shard_stats();
        assert!(stats.iter().any(|&(_, d)| d > 0), "machine was stepped");
    }

    #[test]
    fn egress_deals_on_schedule_with_blocking_byte_accounting() {
        let reactor = Reactor::new(1).unwrap();
        let d = 3;
        let mut outs = Vec::new();
        let mut downs = Vec::new();
        for _ in 0..d {
            let (a, b) = Conn::local_pair(8);
            outs.push(a);
            downs.push(b);
        }
        let labels = (0..d).map(|j| format!("replica{j}")).collect();
        let sender = DealSender::new(outs, labels, 0, 1);
        let mut sink = reactor.register_egress(sender, 8).unwrap();
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..7u64 {
            sink.send_data(&data_msg(f), &link, &c).unwrap();
        }
        sink.broadcast_shutdown(&link, &c).unwrap();
        for (j, down) in downs.iter_mut().enumerate() {
            let mut expect = j as u64;
            loop {
                let m = down.recv(&ByteCounter::new()).unwrap();
                if m.msg_type == MessageType::Shutdown {
                    break;
                }
                assert_eq!(m.frame, expect, "replica {j}");
                expect += d as u64;
            }
            assert!(expect >= 7, "replica {j} starved");
        }
        // Identical accounting to the blocking DealSender: 7 data frames
        // plus exactly one counted shutdown marker.
        let shutdown_wire = Message::control(MessageType::Shutdown).wire_size();
        let data_wire = data_msg(0).wire_size();
        assert_eq!(c.total(), 7 * data_wire + shutdown_wire);
    }

    #[test]
    fn tcp_round_trip_through_both_machines() {
        // sink -> TCP socket -> ingress machine -> pipe, with payloads
        // big enough to exercise partial-write/partial-read resume.
        let reactor = Reactor::new(2).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let dial = Conn::tcp_connect(&addr, "ingress side").unwrap();
        let accepted = Conn::tcp_accept(&listener).unwrap();

        let sender = DealSender::single(dial, "ingress side");
        let mut sink = reactor.register_egress(sender, 4).unwrap();
        let merge = MergeReceiver::single(accepted, "egress side");
        let (tx, rx) = pipe::<Message>(4);
        let err = reactor.register_ingress(merge, tx, None).unwrap();

        let link = Link::ideal();
        let c = ByteCounter::new();
        let payload: Vec<u8> = (0..200_000u32).map(|i| (i * 7 + 13) as u8).collect();
        for f in 0..5u64 {
            let msg = Message {
                msg_type: MessageType::Data,
                frame: f,
                serialized_len: payload.len() as u64,
                count: 0,
                batch: 1,
                payload: payload.clone(),
            };
            sink.send_data(&msg, &link, &c).unwrap();
        }
        sink.broadcast_shutdown(&link, &c).unwrap();
        for f in 0..5u64 {
            let m = rx.recv().unwrap();
            assert_eq!(m.frame, f);
            assert_eq!(m.payload, payload, "frame {f} corrupted in flight");
        }
        assert_eq!(rx.recv().unwrap().msg_type, MessageType::Shutdown);
        assert!(rx.recv().is_none());
        assert!(err.lock().unwrap().is_none());
    }

    #[test]
    fn zero_frame_shutdown_drains_cleanly() {
        let reactor = Reactor::new(1).unwrap();
        let (a, b) = Conn::local_pair(4);
        let mut sink = reactor
            .register_egress(DealSender::single(a, "downstream"), 4)
            .unwrap();
        let (tx, rx) = pipe::<Message>(4);
        let err = reactor
            .register_ingress(MergeReceiver::single(b, "upstream"), tx, None)
            .unwrap();
        sink.broadcast_shutdown(&Link::ideal(), &ByteCounter::new())
            .unwrap();
        assert_eq!(rx.recv().unwrap().msg_type, MessageType::Shutdown);
        assert!(rx.recv().is_none());
        assert!(err.lock().unwrap().is_none());
    }

    #[test]
    fn dead_peer_errors_name_the_peer() {
        // Egress side: the consuming endpoint disappears mid-stream.
        let reactor = Reactor::new(1).unwrap();
        let (a, b) = Conn::local_pair(1);
        let mut sink = reactor
            .register_egress(DealSender::single(a, "node1.1 data socket"), 1)
            .unwrap();
        drop(b);
        let link = Link::ideal();
        let c = ByteCounter::new();
        let mut last = None;
        for f in 0..100u64 {
            match sink.send_data(&data_msg(f), &link, &c) {
                Ok(()) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Err(e) => {
                    last = Some(e);
                    break;
                }
            }
        }
        let e = last.expect("writer must fail once the machine retires");
        assert!(
            format!("{e}").contains("node1.1 data socket"),
            "unlabelled error: {e}"
        );

        // Ingress side: the sending endpoint disappears mid-stream.
        let (a, b) = Conn::local_pair(1);
        let (tx, rx) = pipe::<Message>(4);
        let err = reactor
            .register_ingress(MergeReceiver::single(b, "node0 data socket"), tx, None)
            .unwrap();
        drop(a);
        assert!(rx.recv().is_none(), "pipe closes on machine failure");
        let e = err.lock().unwrap().take().expect("error stashed");
        assert!(
            format!("{e}").contains("node0 data socket"),
            "unlabelled error: {e}"
        );
    }

    #[test]
    fn replicated_mesh_preserves_fifo_end_to_end() {
        // dispatcher -> 2 replicas -> dispatcher, all four machine sets
        // on the reactor: sink deals to the replicas, each replica's
        // ingress feeds a relay thread that re-emits through its own
        // sink, and the final ingress restores global order.
        let reactor = Reactor::new(2).unwrap();
        let u = 2;
        let mut to_replica = Vec::new();
        let mut replica_in = Vec::new();
        for _ in 0..u {
            let (a, b) = Conn::local_pair(4);
            to_replica.push(a);
            replica_in.push(b);
        }
        let mut replica_out = Vec::new();
        let mut ret = Vec::new();
        for _ in 0..u {
            let (a, b) = Conn::local_pair(4);
            replica_out.push(a);
            ret.push(b);
        }
        let labels: Vec<String> = (0..u).map(|i| format!("replica{i}")).collect();
        let mut sink = reactor
            .register_egress(DealSender::new(to_replica, labels.clone(), 0, 1), 4)
            .unwrap();

        let mut relays = Vec::new();
        for (inn, out) in replica_in.into_iter().zip(replica_out.into_iter()) {
            let (tx, rx) = pipe::<Message>(4);
            reactor
                .register_ingress(MergeReceiver::single(inn, "dispatcher"), tx, None)
                .unwrap();
            let mut out_sink = reactor
                .register_egress(
                    DealSender::single(out, "dispatcher return socket"),
                    4,
                )
                .unwrap();
            relays.push(std::thread::spawn(move || {
                let link = Link::ideal();
                let c = ByteCounter::new();
                while let Some(msg) = rx.recv() {
                    if msg.msg_type == MessageType::Shutdown {
                        out_sink.broadcast_shutdown(&link, &c).unwrap();
                        break;
                    }
                    out_sink.send_data(&msg, &link, &c).unwrap();
                }
            }));
        }

        let (tx, rx) = pipe::<Message>(8);
        // merge_schedule(0, u=2, d=1) = (0, 1): alternate the replicas.
        let err = reactor
            .register_ingress(MergeReceiver::new(ret, labels, 0, 1), tx, None)
            .unwrap();
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..9u64 {
            sink.send_data(&data_msg(f), &link, &c).unwrap();
        }
        sink.broadcast_shutdown(&link, &c).unwrap();
        for f in 0..9u64 {
            assert_eq!(rx.recv().unwrap().frame, f, "global FIFO broken");
        }
        assert_eq!(rx.recv().unwrap().msg_type, MessageType::Shutdown);
        for r in relays {
            r.join().unwrap();
        }
        assert!(err.lock().unwrap().is_none());
    }
}
