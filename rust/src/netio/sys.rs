//! Thin epoll/eventfd FFI for the reactor data plane.
//!
//! The reactor needs exactly two kernel facilities: readiness
//! notification for nonblocking TCP sockets (`epoll`) and a cheap
//! cross-thread wakeup primitive that can live in the same wait set
//! (`eventfd`). Rust's standard library already links libc on Linux, so
//! the handful of syscall wrappers here declare their own `extern "C"`
//! prototypes instead of pulling in the `libc` crate — no new
//! dependencies, per the repo's constraints.
//!
//! Everything returns `io::Result` with `errno` captured via
//! `io::Error::last_os_error()`, and `epoll_wait` retries `EINTR`
//! internally so callers never see spurious interrupts.

use std::io;
use std::os::unix::io::RawFd;

pub const EPOLL_CTL_ADD: i32 = 1;
pub const EPOLL_CTL_DEL: i32 = 2;
pub const EPOLL_CTL_MOD: i32 = 3;

pub const EPOLLIN: u32 = 0x1;
pub const EPOLLOUT: u32 = 0x4;
pub const EPOLLERR: u32 = 0x8;
pub const EPOLLHUP: u32 = 0x10;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLONESHOT: u32 = 1 << 30;

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;

/// Mirrors the kernel's `struct epoll_event`. On x86-64 the kernel ABI
/// packs it (no padding between `events` and `data`); other
/// architectures use natural alignment.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

/// Mirrors the kernel's `struct iovec` for [`writev`].
#[repr(C)]
pub struct IoVec {
    pub iov_base: *const u8,
    pub iov_len: usize,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn writev(fd: i32, iov: *const IoVec, iovcnt: i32) -> isize;
    fn close(fd: i32) -> i32;
}

/// Create a close-on-exec epoll instance.
pub fn epoll_create() -> io::Result<RawFd> {
    let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(fd)
}

fn ctl(epfd: RawFd, op: i32, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    let mut ev = EpollEvent { events, data };
    let rc = unsafe { epoll_ctl(epfd, op, fd, &mut ev) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Register `fd` with interest `events`, tagging readiness with `data`.
pub fn epoll_add(epfd: RawFd, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    ctl(epfd, EPOLL_CTL_ADD, fd, events, data)
}

/// Re-arm or change interest for an already-registered `fd`.
pub fn epoll_mod(epfd: RawFd, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    ctl(epfd, EPOLL_CTL_MOD, fd, events, data)
}

/// Remove `fd` from the wait set (closing the fd does this implicitly).
pub fn epoll_del(epfd: RawFd, fd: RawFd) -> io::Result<()> {
    ctl(epfd, EPOLL_CTL_DEL, fd, 0, 0)
}

/// Block until at least one event is ready (or `timeout_ms` elapses;
/// `-1` = forever). Retries `EINTR`. Returns the number of events
/// written into `events`.
pub fn epoll_pwait(epfd: RawFd, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe {
            epoll_wait(
                epfd,
                events.as_mut_ptr(),
                events.len().min(i32::MAX as usize) as i32,
                timeout_ms,
            )
        };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Create a nonblocking close-on-exec eventfd (counter starts at 0).
pub fn eventfd_new() -> io::Result<RawFd> {
    let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(fd)
}

/// Bump the eventfd counter, waking any epoll waiter that has it
/// registered for `EPOLLIN`. Errors are deliberately ignored: the only
/// failure modes are a full counter (still readable, so the wakeup is
/// not lost) or a racing close during teardown (the waiter is gone).
pub fn eventfd_signal(fd: RawFd) {
    let one: u64 = 1;
    let buf = one.to_ne_bytes();
    let _ = unsafe { write(fd, buf.as_ptr(), buf.len()) };
}

/// Drain the eventfd counter back to zero. Nonblocking: `EAGAIN`
/// (already zero) is not an error.
pub fn eventfd_drain(fd: RawFd) {
    let mut buf = [0u8; 8];
    let _ = unsafe { read(fd, buf.as_mut_ptr(), buf.len()) };
}

/// Close a raw fd acquired from [`epoll_create`] or [`eventfd_new`].
pub fn close_fd(fd: RawFd) {
    let _ = unsafe { close(fd) };
}

/// Gather-write up to two byte slices to `fd` in **one** syscall. Empty
/// slices are skipped (the kernel accepts zero-length iovecs, but
/// skipping keeps `iovcnt` honest). Returns the number of bytes written
/// — like `write`, this may be short; the caller resumes across the
/// iovec boundary ([`crate::wire::write_all_vectored`]-style).
pub fn writev2(fd: RawFd, a: &[u8], b: &[u8]) -> io::Result<usize> {
    let mut iov = [
        IoVec {
            iov_base: a.as_ptr(),
            iov_len: a.len(),
        },
        IoVec {
            iov_base: b.as_ptr(),
            iov_len: b.len(),
        },
    ];
    let mut cnt = 2;
    if a.is_empty() {
        iov[0] = IoVec {
            iov_base: b.as_ptr(),
            iov_len: b.len(),
        };
        cnt = 1;
    }
    if b.is_empty() {
        cnt -= 1;
    }
    if cnt == 0 {
        return Ok(0);
    }
    let rc = unsafe { writev(fd, iov.as_ptr(), cnt) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(rc as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_signal_wakes_epoll_with_token() {
        let ep = epoll_create().unwrap();
        let ev = eventfd_new().unwrap();
        epoll_add(ep, ev, EPOLLIN, 0xDEAD_BEEF).unwrap();
        // Nothing pending yet: a zero-timeout wait returns no events.
        let mut events = [EpollEvent { events: 0, data: 0 }; 4];
        assert_eq!(epoll_pwait(ep, &mut events, 0).unwrap(), 0);
        // Signal from this thread, then wait: the token comes back.
        eventfd_signal(ev);
        let n = epoll_pwait(ep, &mut events, 1000).unwrap();
        assert_eq!(n, 1);
        // Copy fields out of the (possibly packed) struct before use.
        let data = { events[0].data };
        let bits = { events[0].events };
        assert_eq!(data, 0xDEAD_BEEF);
        assert!(bits & EPOLLIN != 0);
        // Drain resets the counter; the level-triggered source goes idle.
        eventfd_drain(ev);
        assert_eq!(epoll_pwait(ep, &mut events, 0).unwrap(), 0);
        epoll_del(ep, ev).unwrap();
        close_fd(ev);
        close_fd(ep);
    }

    #[test]
    fn writev2_gathers_both_slices_and_skips_empty_ones() {
        use std::io::Read;
        use std::os::unix::io::AsRawFd;
        let (mut rx, tx) = std::os::unix::net::UnixStream::pair().unwrap();
        assert_eq!(writev2(tx.as_raw_fd(), &[1, 2, 3], &[4, 5]).unwrap(), 5);
        assert_eq!(writev2(tx.as_raw_fd(), &[], &[6]).unwrap(), 1);
        assert_eq!(writev2(tx.as_raw_fd(), &[7], &[]).unwrap(), 1);
        assert_eq!(writev2(tx.as_raw_fd(), &[], &[]).unwrap(), 0);
        let mut got = [0u8; 7];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(got, [1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn cross_thread_signal_wakes_a_blocked_wait() {
        let ep = epoll_create().unwrap();
        let ev = eventfd_new().unwrap();
        epoll_add(ep, ev, EPOLLIN, 7).unwrap();
        let waker = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            eventfd_signal(ev);
        });
        let mut events = [EpollEvent { events: 0, data: 0 }; 1];
        let n = epoll_pwait(ep, &mut events, 5000).unwrap();
        assert_eq!(n, 1);
        let data = { events[0].data };
        assert_eq!(data, 7);
        waker.join().unwrap();
        eventfd_drain(ev);
        close_fd(ev);
        close_fd(ep);
    }
}
