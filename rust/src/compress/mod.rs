//! Compression substrate.
//!
//! The paper compresses every socket payload (architecture, weights,
//! intermediate activations) optionally with LZ4; `lz4.rs` implements the
//! LZ4 *block format* from scratch (no external codec crates offline).

pub mod lz4;

use std::borrow::Cow;

use crate::error::Result;

/// Compression scheme for one socket, as swept by Tables I/II.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Compression {
    /// No compression (paper's "Uncompressed").
    None,
    /// LZ4 block format.
    Lz4,
}

impl Compression {
    pub fn name(self) -> &'static str {
        match self {
            Compression::None => "Uncompressed",
            Compression::Lz4 => "LZ4",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "uncompressed" => Ok(Compression::None),
            "lz4" => Ok(Compression::Lz4),
            other => Err(crate::error::DeferError::Config(format!(
                "unknown compression {other:?} (want none|lz4)"
            ))),
        }
    }

    /// Compress a buffer. `None` is the identity.
    pub fn compress(self, data: &[u8]) -> Vec<u8> {
        match self {
            Compression::None => data.to_vec(),
            Compression::Lz4 => lz4::compress(data),
        }
    }

    /// Compress an owned buffer. The `None` arm is a zero-copy
    /// passthrough (the input *is* the output); `Lz4` compresses into
    /// `scratch` when provided (reusing its capacity) and returns it,
    /// handing `data` back through `reclaimed` so a pool can recycle it.
    pub fn compress_vec(self, data: Vec<u8>, scratch: Option<Vec<u8>>) -> (Vec<u8>, Option<Vec<u8>>) {
        self.compress_vec_with(data, scratch, None)
    }

    /// [`Compression::compress_vec`] drawing the LZ4 hash table from a
    /// shared [`lz4::ScratchPool`] instead of zeroing a fresh 256 KiB
    /// per call — the allocation-free steady state of the frame path.
    /// Identical output bytes with or without the pool.
    pub fn compress_vec_with(
        self,
        data: Vec<u8>,
        scratch: Option<Vec<u8>>,
        tables: Option<&lz4::ScratchPool>,
    ) -> (Vec<u8>, Option<Vec<u8>>) {
        match self {
            Compression::None => (data, scratch),
            Compression::Lz4 => {
                let mut out = scratch.unwrap_or_default();
                match tables {
                    Some(pool) => {
                        let mut table = pool.take();
                        lz4::compress_with(&data, &mut out, &mut table);
                        pool.put(table);
                    }
                    None => lz4::compress_into(&data, &mut out),
                }
                (out, Some(data))
            }
        }
    }

    /// Decompress; `expected` is the known decompressed size for LZ4
    /// (travels in the wire header).
    pub fn decompress(self, data: &[u8], expected: usize) -> Result<Vec<u8>> {
        match self {
            Compression::None => Ok(data.to_vec()),
            Compression::Lz4 => lz4::decompress(data, expected),
        }
    }

    /// Decompress without copying the `None` arm: `Uncompressed` payloads
    /// are borrowed straight from the wire buffer, only `Lz4` allocates.
    pub fn decompress_cow<'a>(self, data: &'a [u8], expected: usize) -> Result<Cow<'a, [u8]>> {
        match self {
            Compression::None => Ok(Cow::Borrowed(data)),
            Compression::Lz4 => Ok(Cow::Owned(lz4::decompress(data, expected)?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Compression::parse("lz4").unwrap(), Compression::Lz4);
        assert_eq!(Compression::parse("None").unwrap(), Compression::None);
        assert!(Compression::parse("zstd").is_err());
    }

    #[test]
    fn none_is_identity() {
        let data = b"hello world".to_vec();
        let c = Compression::None.compress(&data);
        assert_eq!(c, data);
        assert_eq!(Compression::None.decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn none_arm_is_zero_copy() {
        let data = b"payload bytes".to_vec();
        let ptr = data.as_ptr();
        let (out, reclaimed) = Compression::None.compress_vec(data, None);
        // Same allocation passed through, nothing reclaimed.
        assert_eq!(out.as_ptr(), ptr);
        assert!(reclaimed.is_none());
        let cow = Compression::None.decompress_cow(&out, out.len()).unwrap();
        assert!(matches!(cow, Cow::Borrowed(_)));
        assert_eq!(&*cow, b"payload bytes");
    }

    #[test]
    fn lz4_vec_path_matches_slice_path() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let via_slice = Compression::Lz4.compress(&data);
        let (via_vec, reclaimed) = Compression::Lz4.compress_vec(data.clone(), Some(Vec::new()));
        assert_eq!(via_slice, via_vec);
        assert_eq!(reclaimed.as_deref(), Some(data.as_slice()));
        let cow = Compression::Lz4.decompress_cow(&via_vec, data.len()).unwrap();
        assert!(matches!(cow, Cow::Owned(_)));
        assert_eq!(&*cow, data.as_slice());
    }
}
