//! Compression substrate.
//!
//! The paper compresses every socket payload (architecture, weights,
//! intermediate activations) optionally with LZ4; `lz4.rs` implements the
//! LZ4 *block format* from scratch (no external codec crates offline).

pub mod lz4;

use crate::error::Result;

/// Compression scheme for one socket, as swept by Tables I/II.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Compression {
    /// No compression (paper's "Uncompressed").
    None,
    /// LZ4 block format.
    Lz4,
}

impl Compression {
    pub fn name(self) -> &'static str {
        match self {
            Compression::None => "Uncompressed",
            Compression::Lz4 => "LZ4",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "uncompressed" => Ok(Compression::None),
            "lz4" => Ok(Compression::Lz4),
            other => Err(crate::error::DeferError::Config(format!(
                "unknown compression {other:?} (want none|lz4)"
            ))),
        }
    }

    /// Compress a buffer. `None` is the identity.
    pub fn compress(self, data: &[u8]) -> Vec<u8> {
        match self {
            Compression::None => data.to_vec(),
            Compression::Lz4 => lz4::compress(data),
        }
    }

    /// Decompress; `expected` is the known decompressed size for LZ4
    /// (travels in the wire header).
    pub fn decompress(self, data: &[u8], expected: usize) -> Result<Vec<u8>> {
        match self {
            Compression::None => Ok(data.to_vec()),
            Compression::Lz4 => lz4::decompress(data, expected),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Compression::parse("lz4").unwrap(), Compression::Lz4);
        assert_eq!(Compression::parse("None").unwrap(), Compression::None);
        assert!(Compression::parse("zstd").is_err());
    }

    #[test]
    fn none_is_identity() {
        let data = b"hello world".to_vec();
        let c = Compression::None.compress(&data);
        assert_eq!(c, data);
        assert_eq!(Compression::None.decompress(&c, data.len()).unwrap(), data);
    }
}
