//! LZ4 block format, from scratch.
//!
//! Implements the documented LZ4 block format
//! (https://github.com/lz4/lz4/blob/dev/doc/lz4_Block_format.md):
//!
//! A block is a sequence of *sequences*: `[token][literal-len*][literals]
//! [offset u16le][match-len*]`, where the token's high nibble is the literal
//! length (15 = extension bytes follow) and the low nibble is match length
//! minus 4 (the minimum match). The final sequence is literals-only.
//!
//! The compressor uses a 16-bit hash table over 4-byte prefixes with greedy
//! match extension — the same structure as the reference `LZ4_compress_fast`
//! path. Compression ratio on float payloads lands in the same band the
//! paper reports (~25% on weight arrays), which is what Tables I/II need.
//!
//! Hot paths are word-level (§Perf): match extension compares eight bytes
//! per step via XOR + `trailing_zeros`, and the hash table lives in a
//! reusable [`Lz4Scratch`] whose epoch base makes "clearing" it a single
//! add instead of re-zeroing 256 KiB per frame ([`ScratchPool`] shares
//! warm tables across codec workers). All of it is byte-identical to the
//! byte-at-a-time/fresh-table code it replaced.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{DeferError, Result};

const MIN_MATCH: usize = 4;
/// Matches must start at least this far from the end (format rule: the last
/// 5 bytes are always literals; matches must not start within 12 bytes).
const MF_LIMIT: usize = 12;
const LAST_LITERALS: usize = 5;
const HASH_LOG: usize = 16;
const MAX_OFFSET: usize = 65_535;

#[inline]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2654435761) >> (32 - HASH_LOG)) as usize
}

#[inline]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(b[i..i + 4].try_into().unwrap())
}

#[inline]
fn read_u64(b: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(b[i..i + 8].try_into().unwrap())
}

fn write_length(out: &mut Vec<u8>, mut len: usize) {
    while len >= 255 {
        out.push(255);
        len -= 255;
    }
    out.push(len as u8);
}

/// Reusable compressor state: the prefix hash table plus an epoch base.
/// Entries are stored as `base + position + 1` and trusted only when
/// `entry > base`, so starting a new compression is one add — stale
/// entries from earlier payloads read as empty without touching memory.
/// Equivalent by construction to a freshly zeroed table (`base == 0`
/// degenerates to exactly the old layout).
pub struct Lz4Scratch {
    table: Vec<u32>,
    base: u32,
}

impl Default for Lz4Scratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Lz4Scratch {
    pub fn new() -> Self {
        Lz4Scratch {
            table: vec![0u32; 1 << HASH_LOG],
            base: 0,
        }
    }

    /// Open a new epoch for an `n`-byte input and return its base.
    /// Positions stored this call reach `base + n + 1`; if that would
    /// wrap u32, fall back to a real re-zero (rare: once per ~4 GiB of
    /// compressed input per scratch).
    fn begin(&mut self, n: usize) -> u32 {
        let span = (n as u64).min(u32::MAX as u64) as u32;
        if self.base as u64 + span as u64 + 1 > u32::MAX as u64 {
            self.table.fill(0);
            self.base = 0;
        }
        let base = self.base;
        self.base = base + span + 1;
        base
    }
}

/// Bounded pool of warm [`Lz4Scratch`] tables shared by codec workers —
/// the per-frame hot path draws one instead of allocating and zeroing
/// 256 KiB per call (`tests/codec_kernels.rs` asserts the steady state
/// stops missing). `misses()` counts draws that built a new table.
#[derive(Default)]
pub struct ScratchPool {
    pool: Mutex<Vec<Lz4Scratch>>,
    misses: AtomicU64,
}

/// Tables retained by a [`ScratchPool`]: enough for every codec worker
/// plus the coordinator threads of a busy node.
const SCRATCH_POOL_CAP: usize = 32;

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn take(&self) -> Lz4Scratch {
        if let Some(s) = self.pool.lock().unwrap().pop() {
            return s;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Lz4Scratch::new()
    }

    pub fn put(&self, scratch: Lz4Scratch) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(scratch);
        }
    }

    /// Tables currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.lock().unwrap().len()
    }

    /// Draws that had to allocate because the pool was empty. A steady
    /// per-frame loop must stop incrementing this after warm-up.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Compress `src` into a fresh LZ4 block.
pub fn compress(src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    compress_into(src, &mut out);
    out
}

/// Compress `src` into `out` (cleared first), reusing its capacity —
/// the pooled-buffer variant of [`compress`] for the per-frame hot path.
pub fn compress_into(src: &[u8], out: &mut Vec<u8>) {
    compress_with(src, out, &mut Lz4Scratch::new());
}

/// [`compress_into`] with caller-owned scratch: identical output bytes,
/// no per-call table allocation.
pub fn compress_with(src: &[u8], out: &mut Vec<u8>, scratch: &mut Lz4Scratch) {
    out.clear();
    let n = src.len();
    if n == 0 {
        // A single empty-literal token terminates the block.
        out.push(0);
        return;
    }
    let base = scratch.begin(n);
    let table = &mut scratch.table;
    let mut anchor = 0usize; // start of pending literals
    let mut i = 0usize;

    if n > MF_LIMIT {
        let match_limit = n - MF_LIMIT;
        while i <= match_limit {
            let h = hash4(read_u32(src, i));
            let entry = table[h];
            table[h] = base + i as u32 + 1;
            let found = entry > base && {
                let c = (entry - base - 1) as usize;
                i - c <= MAX_OFFSET && read_u32(src, c) == read_u32(src, i)
            };
            if !found {
                i += 1;
                continue;
            }
            let cand = (entry - base - 1) as usize;

            // Extend the match forward, eight bytes per step: a nonzero
            // XOR's trailing zeros count the matching low-order bytes of
            // the little-endian loads. The input ends with LAST_LITERALS
            // literals, so the extension is capped and every word load
            // stays in bounds (`i + max_len == n - 5`, `cand < i`).
            let mut mlen = MIN_MATCH;
            let max_len = n - LAST_LITERALS - i;
            while mlen + 8 <= max_len {
                let x = read_u64(src, cand + mlen) ^ read_u64(src, i + mlen);
                if x != 0 {
                    mlen += (x.trailing_zeros() >> 3) as usize;
                    break;
                }
                mlen += 8;
            }
            // Byte-wise tail (no-op if the word loop ended on a mismatch).
            while mlen < max_len && src[cand + mlen] == src[i + mlen] {
                mlen += 1;
            }

            // Emit sequence: literals [anchor, i) + match (offset, mlen).
            let lit_len = i - anchor;
            let token_lit = lit_len.min(15) as u8;
            let token_match = (mlen - MIN_MATCH).min(15) as u8;
            out.push((token_lit << 4) | token_match);
            if lit_len >= 15 {
                write_length(out, lit_len - 15);
            }
            out.extend_from_slice(&src[anchor..i]);
            let offset = (i - cand) as u16;
            out.extend_from_slice(&offset.to_le_bytes());
            if mlen - MIN_MATCH >= 15 {
                write_length(out, mlen - MIN_MATCH - 15);
            }

            // Seed the table inside the match for better chaining.
            let step = ((mlen / 8).max(1)).min(7);
            let mut j = i + 1;
            while j + 4 <= i + mlen && j <= match_limit {
                table[hash4(read_u32(src, j))] = base + j as u32 + 1;
                j += step;
            }

            i += mlen;
            anchor = i;
        }
    }

    // Trailing literals-only sequence.
    let lit_len = n - anchor;
    out.push((lit_len.min(15) as u8) << 4);
    if lit_len >= 15 {
        write_length(out, lit_len - 15);
    }
    out.extend_from_slice(&src[anchor..]);
}

/// Decompress a block produced by [`compress`] (or any conformant encoder).
/// `expected` is the exact decompressed size (carried in the wire header).
pub fn decompress(src: &[u8], expected: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expected);
    let mut i = 0usize;
    let err = |msg: &str| DeferError::Codec(format!("lz4: {msg}"));

    loop {
        let token = *src.get(i).ok_or_else(|| err("truncated token"))?;
        i += 1;

        // Literals.
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            loop {
                let b = *src.get(i).ok_or_else(|| err("truncated literal len"))?;
                i += 1;
                lit_len += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        let lit_end = i.checked_add(lit_len).ok_or_else(|| err("lit overflow"))?;
        if lit_end > src.len() {
            return Err(err("literals past end"));
        }
        out.extend_from_slice(&src[i..lit_end]);
        i = lit_end;

        if i == src.len() {
            break; // final literals-only sequence
        }

        // Match.
        if i + 2 > src.len() {
            return Err(err("truncated offset"));
        }
        let offset = u16::from_le_bytes([src[i], src[i + 1]]) as usize;
        i += 2;
        if offset == 0 || offset > out.len() {
            return Err(err("bad offset"));
        }
        let mut mlen = (token & 0x0F) as usize + MIN_MATCH;
        if token & 0x0F == 0x0F {
            loop {
                let b = *src.get(i).ok_or_else(|| err("truncated match len"))?;
                i += 1;
                mlen += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        let start = out.len() - offset;
        if offset >= mlen {
            // Disjoint source and destination: one bulk copy.
            out.extend_from_within(start..start + mlen);
        } else {
            // Overlapping copy must be byte-wise (it *generates* runs).
            out.reserve(mlen);
            for k in 0..mlen {
                let b = out[start + k];
                out.push(b);
            }
        }
        if out.len() > expected {
            return Err(err("output exceeds expected size"));
        }
    }

    if out.len() != expected {
        return Err(err(&format!(
            "decompressed {} bytes, expected {expected}",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn round_trip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn empty_and_tiny() {
        round_trip(b"");
        round_trip(b"a");
        round_trip(b"abc");
        round_trip(b"aaaaaaaaaaaa");
        round_trip(b"hello hello hello hello hello");
    }

    #[test]
    fn long_runs_compress_well() {
        let data = vec![7u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < data.len() / 100, "run-length ratio {}", c.len());
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn incompressible_random_survives() {
        let mut rng = Rng::new(11);
        for n in [1, 13, 100, 4096, 100_000] {
            let data = rng.bytes(n);
            let c = compress(&data);
            // Expansion is bounded (~0.4% + few bytes).
            assert!(c.len() <= n + n / 128 + 32);
            assert_eq!(decompress(&c, n).unwrap(), data);
        }
    }

    #[test]
    fn compressible_streams_round_trip() {
        let mut rng = Rng::new(12);
        for n in [64, 1000, 65_536, 300_000] {
            let data = rng.compressible_bytes(n);
            let c = compress(&data);
            assert!(c.len() < data.len(), "should compress: {n}");
            assert_eq!(decompress(&c, n).unwrap(), data);
        }
    }

    #[test]
    fn overlapping_match_copy() {
        // "abcabcabc..." forces offset < match-length copies.
        let data: Vec<u8> = b"abc".iter().copied().cycle().take(10_000).collect();
        round_trip(&data);
    }

    #[test]
    fn reused_scratch_matches_fresh_table() {
        // The epoch-base trick must be invisible in the output: one
        // scratch carried across many payloads produces byte-for-byte
        // what a fresh table produces for each.
        let mut rng = Rng::new(15);
        let mut scratch = Lz4Scratch::new();
        let mut out = Vec::new();
        for round in 0..50 {
            let n = rng.range(0, 8000);
            let data = if rng.below(2) == 0 {
                rng.bytes(n)
            } else {
                rng.compressible_bytes(n.max(1))
            };
            compress_with(&data, &mut out, &mut scratch);
            assert_eq!(out, compress(&data), "round {round} n {n}");
            assert_eq!(decompress(&out, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn scratch_epoch_wraparound_rezeros() {
        // Force the u32 epoch base to the wraparound path: output must
        // still match a fresh table exactly.
        let mut rng = Rng::new(16);
        let data = rng.compressible_bytes(4096);
        let expect = compress(&data);
        let mut scratch = Lz4Scratch::new();
        scratch.base = u32::MAX - 100; // stale garbage above any new base
        scratch.table.fill(u32::MAX - 50);
        let mut out = Vec::new();
        compress_with(&data, &mut out, &mut scratch);
        assert_eq!(out, expect);
        assert_eq!(scratch.base, 4096 + 1);
        // And the epoch after the reset still matches.
        compress_with(&data, &mut out, &mut scratch);
        assert_eq!(out, expect);
    }

    #[test]
    fn scratch_pool_reuses_tables() {
        let pool = ScratchPool::new();
        assert_eq!(pool.misses(), 0);
        let a = pool.take();
        assert_eq!(pool.misses(), 1);
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let _b = pool.take();
        assert_eq!(pool.misses(), 1, "second take must hit the pool");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn float_payload_ratio_band() {
        // Weight-like payload: the paper reports ~25% savings on f32 arrays
        // (Table I weights: 551 -> 446 MB JSON, 512 -> 309 ZFP+LZ4).
        let mut rng = Rng::new(13);
        let floats: Vec<f32> = (0..50_000).map(|_| rng.normal_f32()).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        let c = compress(&bytes);
        let ratio = c.len() as f64 / bytes.len() as f64;
        assert!(ratio < 1.01, "f32 payloads must not blow up: {ratio}");
    }

    #[test]
    fn corrupt_inputs_rejected() {
        let c = compress(b"The quick brown fox jumps over the lazy dog");
        // Wrong expected size.
        assert!(decompress(&c, 10).is_err());
        assert!(decompress(&c, 1000).is_err());
        // Truncated stream.
        assert!(decompress(&c[..c.len() - 3], 44).is_err());
        // Bad offset: token with match but no history.
        assert!(decompress(&[0x01, b'x', 0xFF, 0xFF, 0x00], 100).is_err());
        // Empty input.
        assert!(decompress(&[], 5).is_err());
    }

    #[test]
    fn large_offset_boundary() {
        // Motif recurrence at ~64k distance exercises the u16 offset limit.
        let mut data = vec![0u8; 70_000];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        round_trip(&data);
    }

    #[test]
    fn property_random_round_trips() {
        let mut rng = Rng::new(14);
        for _ in 0..200 {
            let n = rng.range(0, 5000);
            let data = if rng.below(2) == 0 {
                rng.bytes(n)
            } else {
                rng.compressible_bytes(n.max(1))
            };
            let c = compress(&data);
            assert_eq!(decompress(&c, data.len()).unwrap(), data);
        }
    }
}
