//! LZ4 block format, from scratch.
//!
//! Implements the documented LZ4 block format
//! (https://github.com/lz4/lz4/blob/dev/doc/lz4_Block_format.md):
//!
//! A block is a sequence of *sequences*: `[token][literal-len*][literals]
//! [offset u16le][match-len*]`, where the token's high nibble is the literal
//! length (15 = extension bytes follow) and the low nibble is match length
//! minus 4 (the minimum match). The final sequence is literals-only.
//!
//! The compressor uses a 16-bit hash table over 4-byte prefixes with greedy
//! match extension — the same structure as the reference `LZ4_compress_fast`
//! path. Compression ratio on float payloads lands in the same band the
//! paper reports (~25% on weight arrays), which is what Tables I/II need.

use crate::error::{DeferError, Result};

const MIN_MATCH: usize = 4;
/// Matches must start at least this far from the end (format rule: the last
/// 5 bytes are always literals; matches must not start within 12 bytes).
const MF_LIMIT: usize = 12;
const LAST_LITERALS: usize = 5;
const HASH_LOG: usize = 16;
const MAX_OFFSET: usize = 65_535;

#[inline]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2654435761) >> (32 - HASH_LOG)) as usize
}

#[inline]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]])
}

fn write_length(out: &mut Vec<u8>, mut len: usize) {
    while len >= 255 {
        out.push(255);
        len -= 255;
    }
    out.push(len as u8);
}

/// Compress `src` into a fresh LZ4 block.
pub fn compress(src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    compress_into(src, &mut out);
    out
}

/// Compress `src` into `out` (cleared first), reusing its capacity —
/// the pooled-buffer variant of [`compress`] for the per-frame hot path.
pub fn compress_into(src: &[u8], out: &mut Vec<u8>) {
    out.clear();
    let n = src.len();
    if n == 0 {
        // A single empty-literal token terminates the block.
        out.push(0);
        return;
    }
    let mut table = vec![0u32; 1 << HASH_LOG]; // position + 1 (0 = empty)
    let mut anchor = 0usize; // start of pending literals
    let mut i = 0usize;

    if n > MF_LIMIT {
        let match_limit = n - MF_LIMIT;
        while i <= match_limit {
            let h = hash4(read_u32(src, i));
            let cand = table[h] as usize;
            table[h] = (i + 1) as u32;
            let found = cand > 0 && {
                let c = cand - 1;
                i - c <= MAX_OFFSET && read_u32(src, c) == read_u32(src, i)
            };
            if !found {
                i += 1;
                continue;
            }
            let cand = cand - 1;

            // Extend the match forward (input ends with LAST_LITERALS
            // literals, so cap the extension).
            let mut mlen = MIN_MATCH;
            let max_len = n - LAST_LITERALS - i;
            while mlen < max_len && src[cand + mlen] == src[i + mlen] {
                mlen += 1;
            }
            if mlen < MIN_MATCH {
                i += 1;
                continue;
            }

            // Emit sequence: literals [anchor, i) + match (offset, mlen).
            let lit_len = i - anchor;
            let token_lit = lit_len.min(15) as u8;
            let token_match = (mlen - MIN_MATCH).min(15) as u8;
            out.push((token_lit << 4) | token_match);
            if lit_len >= 15 {
                write_length(out, lit_len - 15);
            }
            out.extend_from_slice(&src[anchor..i]);
            let offset = (i - cand) as u16;
            out.extend_from_slice(&offset.to_le_bytes());
            if mlen - MIN_MATCH >= 15 {
                write_length(out, mlen - MIN_MATCH - 15);
            }

            // Seed the table inside the match for better chaining.
            let step = ((mlen / 8).max(1)).min(7);
            let mut j = i + 1;
            while j + 4 <= i + mlen && j <= match_limit {
                table[hash4(read_u32(src, j))] = (j + 1) as u32;
                j += step;
            }

            i += mlen;
            anchor = i;
        }
    }

    // Trailing literals-only sequence.
    let lit_len = n - anchor;
    out.push((lit_len.min(15) as u8) << 4);
    if lit_len >= 15 {
        write_length(out, lit_len - 15);
    }
    out.extend_from_slice(&src[anchor..]);
}

/// Decompress a block produced by [`compress`] (or any conformant encoder).
/// `expected` is the exact decompressed size (carried in the wire header).
pub fn decompress(src: &[u8], expected: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expected);
    let mut i = 0usize;
    let err = |msg: &str| DeferError::Codec(format!("lz4: {msg}"));

    loop {
        let token = *src.get(i).ok_or_else(|| err("truncated token"))?;
        i += 1;

        // Literals.
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            loop {
                let b = *src.get(i).ok_or_else(|| err("truncated literal len"))?;
                i += 1;
                lit_len += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        let lit_end = i.checked_add(lit_len).ok_or_else(|| err("lit overflow"))?;
        if lit_end > src.len() {
            return Err(err("literals past end"));
        }
        out.extend_from_slice(&src[i..lit_end]);
        i = lit_end;

        if i == src.len() {
            break; // final literals-only sequence
        }

        // Match.
        if i + 2 > src.len() {
            return Err(err("truncated offset"));
        }
        let offset = u16::from_le_bytes([src[i], src[i + 1]]) as usize;
        i += 2;
        if offset == 0 || offset > out.len() {
            return Err(err("bad offset"));
        }
        let mut mlen = (token & 0x0F) as usize + MIN_MATCH;
        if token & 0x0F == 0x0F {
            loop {
                let b = *src.get(i).ok_or_else(|| err("truncated match len"))?;
                i += 1;
                mlen += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        // Overlapping copy must be byte-wise.
        let start = out.len() - offset;
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
        if out.len() > expected {
            return Err(err("output exceeds expected size"));
        }
    }

    if out.len() != expected {
        return Err(err(&format!(
            "decompressed {} bytes, expected {expected}",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn round_trip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn empty_and_tiny() {
        round_trip(b"");
        round_trip(b"a");
        round_trip(b"abc");
        round_trip(b"aaaaaaaaaaaa");
        round_trip(b"hello hello hello hello hello");
    }

    #[test]
    fn long_runs_compress_well() {
        let data = vec![7u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < data.len() / 100, "run-length ratio {}", c.len());
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn incompressible_random_survives() {
        let mut rng = Rng::new(11);
        for n in [1, 13, 100, 4096, 100_000] {
            let data = rng.bytes(n);
            let c = compress(&data);
            // Expansion is bounded (~0.4% + few bytes).
            assert!(c.len() <= n + n / 128 + 32);
            assert_eq!(decompress(&c, n).unwrap(), data);
        }
    }

    #[test]
    fn compressible_streams_round_trip() {
        let mut rng = Rng::new(12);
        for n in [64, 1000, 65_536, 300_000] {
            let data = rng.compressible_bytes(n);
            let c = compress(&data);
            assert!(c.len() < data.len(), "should compress: {n}");
            assert_eq!(decompress(&c, n).unwrap(), data);
        }
    }

    #[test]
    fn overlapping_match_copy() {
        // "abcabcabc..." forces offset < match-length copies.
        let data: Vec<u8> = b"abc".iter().copied().cycle().take(10_000).collect();
        round_trip(&data);
    }

    #[test]
    fn float_payload_ratio_band() {
        // Weight-like payload: the paper reports ~25% savings on f32 arrays
        // (Table I weights: 551 -> 446 MB JSON, 512 -> 309 ZFP+LZ4).
        let mut rng = Rng::new(13);
        let floats: Vec<f32> = (0..50_000).map(|_| rng.normal_f32()).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        let c = compress(&bytes);
        let ratio = c.len() as f64 / bytes.len() as f64;
        assert!(ratio < 1.01, "f32 payloads must not blow up: {ratio}");
    }

    #[test]
    fn corrupt_inputs_rejected() {
        let c = compress(b"The quick brown fox jumps over the lazy dog");
        // Wrong expected size.
        assert!(decompress(&c, 10).is_err());
        assert!(decompress(&c, 1000).is_err());
        // Truncated stream.
        assert!(decompress(&c[..c.len() - 3], 44).is_err());
        // Bad offset: token with match but no history.
        assert!(decompress(&[0x01, b'x', 0xFF, 0xFF, 0x00], 100).is_err());
        // Empty input.
        assert!(decompress(&[], 5).is_err());
    }

    #[test]
    fn large_offset_boundary() {
        // Motif recurrence at ~64k distance exercises the u16 offset limit.
        let mut data = vec![0u8; 70_000];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        round_trip(&data);
    }

    #[test]
    fn property_random_round_trips() {
        let mut rng = Rng::new(14);
        for _ in 0..200 {
            let n = rng.range(0, 5000);
            let data = if rng.below(2) == 0 {
                rng.bytes(n)
            } else {
                rng.compressible_bytes(n.max(1))
            };
            let c = compress(&data);
            assert_eq!(decompress(&c, data.len()).unwrap(), data);
        }
    }
}
